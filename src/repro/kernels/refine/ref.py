"""Pure-jnp oracle for the refinement kernel (same eps semantics)."""
from __future__ import annotations

import jax.numpy as jnp


def edges_intersect_ref(a0, a1, am, b0, b1, bm, eps: float = 1e-5):
    def orient(p, q, r):
        return ((q[..., 0] - p[..., 0]) * (r[..., 1] - p[..., 1])
                - (q[..., 1] - p[..., 1]) * (r[..., 0] - p[..., 0]))

    A0 = a0[:, :, None, :].astype(jnp.float32)
    A1 = a1[:, :, None, :].astype(jnp.float32)
    B0 = b0[:, None, :, :].astype(jnp.float32)
    B1 = b1[:, None, :, :].astype(jnp.float32)
    d1 = orient(B0, B1, A0)
    d2 = orient(B0, B1, A1)
    d3 = orient(A0, A1, B0)
    d4 = orient(A0, A1, B1)
    valid = am[:, :, None] & bm[:, None, :]
    proper = ((d1 > 0) != (d2 > 0)) & ((d3 > 0) != (d4 > 0))
    scale = (jnp.abs(A1[..., 0] - A0[..., 0]) + jnp.abs(A1[..., 1] - A0[..., 1])
             + jnp.abs(B1[..., 0] - B0[..., 0]) + jnp.abs(B1[..., 1] - B0[..., 1]))
    # scale^2: f32 arithmetic rounding; scale * mag: f64 -> f32 cast error
    mag = (jnp.maximum(jnp.abs(A0[..., 0]), jnp.abs(A0[..., 1]))
           + jnp.maximum(jnp.abs(B0[..., 0]), jnp.abs(B0[..., 1])))
    tol = eps * scale * (scale + mag)
    near0 = (jnp.abs(d1) <= tol) | (jnp.abs(d2) <= tol) \
        | (jnp.abs(d3) <= tol) | (jnp.abs(d4) <= tol)
    boxes = ((jnp.minimum(A0[..., 0], A1[..., 0]) <= jnp.maximum(B0[..., 0], B1[..., 0]) + tol)
             & (jnp.minimum(B0[..., 0], B1[..., 0]) <= jnp.maximum(A0[..., 0], A1[..., 0]) + tol)
             & (jnp.minimum(A0[..., 1], A1[..., 1]) <= jnp.maximum(B0[..., 1], B1[..., 1]) + tol)
             & (jnp.minimum(B0[..., 1], B1[..., 1]) <= jnp.maximum(A0[..., 1], A1[..., 1]) + tol))
    hit = jnp.any(proper & ~near0 & valid, axis=(1, 2))
    unc = jnp.any(near0 & boxes & valid, axis=(1, 2))
    return hit, unc
