from .ops import batch_edges_intersect  # noqa: F401
from .refine import edges_intersect_pallas  # noqa: F401
