from .ops import batch_edges_intersect  # noqa: F401
