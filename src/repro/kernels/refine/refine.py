"""Pallas TPU kernel: batched polygon-boundary intersection tests.

Refinement dominates the end-to-end spatial join (paper §2); its core is an
edge x edge segment-intersection sweep per candidate pair. Each grid program
evaluates a [BB, Ea, EB] tile of orientation predicates on the VPU
(coordinates split into separate x/y planes — a trailing dim of 2 would
waste (8,128) tiling).

f32 on device with an epsilon guard band: any orientation magnitude below
``eps`` (relative) makes the pair *uncertain* rather than decided; the
driver re-checks uncertain pairs on host at f64. Definite hits/misses never
contradict the exact predicate (tested against the f64 oracle).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["edges_intersect_pallas"]


def _kernel(a0x_ref, a0y_ref, a1x_ref, a1y_ref, am_ref,
            b0x_ref, b0y_ref, b1x_ref, b1y_ref, bm_ref,
            hit_ref, unc_ref, *, eps):
    jb = pl.program_id(1)

    a0x = a0x_ref[...]; a0y = a0y_ref[...]       # [BB, Ea]
    a1x = a1x_ref[...]; a1y = a1y_ref[...]
    am = am_ref[...]
    b0x = b0x_ref[...]; b0y = b0y_ref[...]       # [BB, EB]
    b1x = b1x_ref[...]; b1y = b1y_ref[...]
    bm = bm_ref[...]

    def orient(px, py, qx, qy, rx, ry):
        return (qx - px) * (ry - py) - (qy - py) * (rx - px)

    A0x = a0x[:, :, None]; A0y = a0y[:, :, None]
    A1x = a1x[:, :, None]; A1y = a1y[:, :, None]
    B0x = b0x[:, None, :]; B0y = b0y[:, None, :]
    B1x = b1x[:, None, :]; B1y = b1y[:, None, :]

    d1 = orient(B0x, B0y, B1x, B1y, A0x, A0y)
    d2 = orient(B0x, B0y, B1x, B1y, A1x, A1y)
    d3 = orient(A0x, A0y, A1x, A1y, B0x, B0y)
    d4 = orient(A0x, A0y, A1x, A1y, B0x * 0 + B1x, B0y * 0 + B1y)

    valid = am[:, :, None] & bm[:, None, :]
    proper = ((d1 > 0) != (d2 > 0)) & ((d3 > 0) != (d4 > 0))

    # relative guard band: |orient| below eps * scale * (scale + mag). The
    # scale^2 term covers f32 arithmetic rounding; the scale * mag term
    # covers the f64 -> f32 coordinate cast (an absolute perturbation
    # ~eps32 * |coord| which enters the orientation multiplied by the edge
    # length, so short edges far from the origin need the magnitude term).
    scale = (jnp.abs(A1x - A0x) + jnp.abs(A1y - A0y)
             + jnp.abs(B1x - B0x) + jnp.abs(B1y - B0y))
    mag = (jnp.maximum(jnp.abs(A0x), jnp.abs(A0y))
           + jnp.maximum(jnp.abs(B0x), jnp.abs(B0y)))
    tol = eps * scale * (scale + mag)
    near0 = (jnp.abs(d1) <= tol) | (jnp.abs(d2) <= tol) \
        | (jnp.abs(d3) <= tol) | (jnp.abs(d4) <= tol)
    # bounding boxes must overlap for a near-collinear touch to matter
    boxes = ((jnp.minimum(A0x, A1x) <= jnp.maximum(B0x, B1x) + tol)
             & (jnp.minimum(B0x, B1x) <= jnp.maximum(A0x, A1x) + tol)
             & (jnp.minimum(A0y, A1y) <= jnp.maximum(B0y, B1y) + tol)
             & (jnp.minimum(B0y, B1y) <= jnp.maximum(A0y, A1y) + tol))

    hit = jnp.any(proper & ~near0 & valid, axis=(1, 2))
    unc = jnp.any(near0 & boxes & valid, axis=(1, 2))

    @pl.when(jb == 0)
    def _():
        hit_ref[...] = hit
        unc_ref[...] = unc

    @pl.when(jb != 0)
    def _():
        hit_ref[...] = hit_ref[...] | hit
        unc_ref[...] = unc_ref[...] | unc


def edges_intersect_pallas(a0, a1, am, b0, b1, bm, *, eps: float = 1e-5,
                           block_b: int = 8, block_e: int = 128,
                           interpret: bool = False):
    """(hit [B], uncertain [B]). a0/a1: [B, Ea, 2] f32; b0/b1: [B, Eb, 2]."""
    B, Ea, _ = a0.shape
    Eb = b0.shape[1]
    assert B % block_b == 0 and Eb % block_e == 0
    grid = (B // block_b, Eb // block_e)

    def split(p):
        return jnp.asarray(p[..., 0], jnp.float32), jnp.asarray(p[..., 1], jnp.float32)

    a0x, a0y = split(a0); a1x, a1y = split(a1)
    b0x, b0y = split(b0); b1x, b1y = split(b1)

    spec_a = pl.BlockSpec((block_b, Ea), lambda b, j: (b, 0))
    spec_b = pl.BlockSpec((block_b, block_e), lambda b, j: (b, j))
    spec_o = pl.BlockSpec((block_b,), lambda b, j: (b,))

    return pl.pallas_call(
        partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[spec_a] * 4 + [spec_a] + [spec_b] * 4 + [spec_b],
        out_specs=(spec_o, spec_o),
        out_shape=(jax.ShapeDtypeStruct((B,), jnp.bool_),
                   jax.ShapeDtypeStruct((B,), jnp.bool_)),
        interpret=interpret,
    )(a0x, a0y, a1x, a1y, am, b0x, b0y, b1x, b1y, bm)
