"""Wrapper: pad edge batches to tile multiples and dispatch."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .refine import edges_intersect_pallas


def _pad(a, axis, mult, fill):
    size = a.shape[axis]
    target = ((size + mult - 1) // mult) * mult
    if target == size:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, target - size)
    return jnp.pad(a, pad, constant_values=fill)


@partial(jax.jit, static_argnames=("interpret", "eps"))
def batch_edges_intersect(a0, a1, am, b0, b1, bm, *, eps=1e-5, interpret=False):
    """(hit, uncertain) [B] for padded edge batches of any B/Ea/Eb."""
    B = a0.shape[0]
    a0 = _pad(jnp.asarray(a0, jnp.float32), 1, 128, 0.0)
    a1 = _pad(jnp.asarray(a1, jnp.float32), 1, 128, 0.0)
    am = _pad(jnp.asarray(am, bool), 1, 128, False)
    b0 = _pad(jnp.asarray(b0, jnp.float32), 1, 128, 0.0)
    b1 = _pad(jnp.asarray(b1, jnp.float32), 1, 128, 0.0)
    bm = _pad(jnp.asarray(bm, bool), 1, 128, False)
    arrs = [_pad(x, 0, 8, 0) for x in (a0, a1)] + [_pad(am, 0, 8, False)] \
        + [_pad(x, 0, 8, 0) for x in (b0, b1)] + [_pad(bm, 0, 8, False)]
    hit, unc = edges_intersect_pallas(*arrs, eps=eps, interpret=interpret)
    return hit[:B], unc[:B]
