"""Pallas TPU kernels for the performance-critical layers.

Each kernel package ships three files:
  <name>.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (padding, dtype plumbing, interpret flag)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels are validated on CPU with ``interpret=True`` and designed for the
TPU memory hierarchy (HBM->VMEM tiles, (8,128) VPU lanes, MXU-aligned dims).
"""
