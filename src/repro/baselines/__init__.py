from .fivec_ch import FiveCCH, build_5cch, fivecch_verdict_pair  # noqa: F401
from .ra import RAStore, build_ra, ra_verdict_pair  # noqa: F401
