"""5C+CH intermediate filter (Brinkhoff et al. [9]).

Conservative approximations applied in sequence: the minimum-bounding
5-corner convex polygon (realized as a 5-direction DOP: the intersection of
half-planes at five fixed orientations, whose corners we materialize), then
the exact convex hull. Both are conservative-only: they certify TRUE
negatives (approximations disjoint) but never true hits — matching the
paper's observation that 5C+CH detects 0% true hits (Fig. 13, Tables 13/16).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.join import INDECISIVE, TRUE_NEG

__all__ = ["FiveCCH", "build_5cch", "build_5cch_lines",
           "fivecch_verdict_pair", "fivecch_filter_batch",
           "fivecch_within_verdict_pair", "convex_hull"]

# 5 fixed outward normals (72-degree steps)
_ANG = np.pi / 2 + 2 * np.pi * np.arange(5) / 5
_DIRS = np.stack([np.cos(_ANG), np.sin(_ANG)], axis=1)   # [5,2]

# Precompute corner solve matrices for adjacent direction pairs
_CORNER_INV = []
for _k in range(5):
    A = np.stack([_DIRS[_k], _DIRS[(_k + 1) % 5]])
    _CORNER_INV.append(np.linalg.inv(A))


@dataclass
class FiveCCH:
    pent: np.ndarray             # [P,5,2] pentagon corners (CCW)
    hull_off: np.ndarray         # [P+1]
    hull_pts: np.ndarray         # [sum_H, 2]

    def __len__(self):
        return len(self.pent)

    def hull(self, i: int) -> np.ndarray:
        return self.hull_pts[self.hull_off[i]: self.hull_off[i + 1]]

    def size_bytes(self) -> int:
        # 5 corner points per 5C + hull points, float32 pairs
        return 4 * 2 * 5 * len(self.pent) + 4 * 2 * len(self.hull_pts)


def convex_hull(points: np.ndarray) -> np.ndarray:
    """Andrew's monotone chain. points [N,2] -> hull [H,2] CCW."""
    pts = np.unique(np.asarray(points, np.float64), axis=0)
    if len(pts) <= 2:
        return pts
    order = np.lexsort((pts[:, 1], pts[:, 0]))
    pts = pts[order]

    def half(ps):
        out = []
        for p in ps:
            while len(out) >= 2:
                u = out[-1] - out[-2]
                w = p - out[-2]
                if u[0] * w[1] - u[1] * w[0] <= 0:
                    out.pop()
                else:
                    break
            out.append(p)
        return out

    lower = half(list(pts))
    upper = half(list(pts[::-1]))
    return np.asarray(lower[:-1] + upper[:-1])


def _corners_from_support(m: np.ndarray) -> np.ndarray:
    """Solve the 5 adjacent-direction 2x2 systems for support values
    ``m [..., 5]``; explicit elementwise arithmetic so the batched and
    per-object builds are bit-identical. Returns [..., 5, 2]."""
    m1 = np.roll(m, -1, axis=-1)
    inv = np.stack(_CORNER_INV)              # [5,2,2]
    x = inv[:, 0, 0] * m + inv[:, 0, 1] * m1
    y = inv[:, 1, 0] * m + inv[:, 1, 1] * m1
    return np.stack([x, y], axis=-1)


def _pentagon(verts: np.ndarray) -> np.ndarray:
    """Corners of the 5-direction DOP enclosing ``verts``."""
    m = (verts @ _DIRS.T).max(axis=0)        # [5] support values
    return _corners_from_support(m)


def _pentagons_multi(verts: np.ndarray, nverts: np.ndarray) -> np.ndarray:
    """Vectorized :func:`_pentagon` over the padded dataset: masked support
    values, then all corner solves as one einsum. [P,5,2]."""
    verts = np.asarray(verts, np.float64)
    nverts = np.asarray(nverts, np.int64)
    P, V, _ = verts.shape
    valid = np.arange(V)[None, :] < nverts[:, None]
    sup = np.where(valid[..., None], verts @ _DIRS.T, -np.inf).max(axis=1)
    return _corners_from_support(sup)


def build_5cch(dataset, backend: str = "numpy") -> FiveCCH:
    """Build the 5C+CH store. ``backend`` 'numpy' | 'jnp' vectorize the
    pentagon (5-DOP) stage over the whole dataset; 'sequential' is the
    per-object reference. The convex-hull stage is per-object either way
    (monotone chain; cheap relative to rasterizing filters)."""
    P = len(dataset)
    if backend == "sequential":
        pent = np.zeros((P, 5, 2))
        for i in range(P):
            pent[i] = _pentagon(dataset.polygon(i))
    else:
        pent = _pentagons_multi(dataset.verts, dataset.nverts)
    off = [0]; hulls = []
    for i in range(P):
        h = convex_hull(dataset.polygon(i))
        hulls.append(h)
        off.append(off[-1] + len(h))
    return FiveCCH(pent=pent,
                   hull_off=np.asarray(off, np.int64),
                   hull_pts=(np.concatenate(hulls, axis=0) if hulls
                             else np.zeros((0, 2))))


def convex_disjoint(ha: np.ndarray, hb: np.ndarray) -> bool:
    """Separating-axis test for two convex polygons (CCW or CW)."""
    for h0, h1 in ((ha, hb), (hb, ha)):
        edges = np.roll(h0, -1, axis=0) - h0
        normals = np.stack([-edges[:, 1], edges[:, 0]], axis=1)
        p0 = h0 @ normals.T
        p1 = h1 @ normals.T
        sep = (p1.max(axis=0) < p0.min(axis=0)) | (p1.min(axis=0) > p0.max(axis=0))
        if bool(sep.any()):
            return True
    return False


def fivecch_verdict_pair(store_r: FiveCCH, i: int, store_s: FiveCCH, j: int) -> int:
    """5C stage first (cheap), then CH stage; TRUE_NEG or INDECISIVE only."""
    if convex_disjoint(store_r.pent[i], store_s.pent[j]):
        return TRUE_NEG
    ha, hb = store_r.hull(i), store_s.hull(j)
    if len(ha) >= 3 and len(hb) >= 3 and convex_disjoint(ha, hb):
        return TRUE_NEG
    return INDECISIVE


def fivecch_within_verdict_pair(store_r: FiveCCH, i: int, store_s: FiveCCH,
                                j: int) -> int:
    """Within filter: conservative approximations can only certify TRUE_NEG
    (disjoint approximations => r is not within s); never a hit."""
    return fivecch_verdict_pair(store_r, i, store_s, j)


def build_5cch_lines(dataset, backend: str = "numpy") -> FiveCCH:
    """5C+CH store for open linestrings (the pentagon/hull of the chain's
    vertices encloses the chain, so disjointness stays conservative)."""
    return build_5cch(dataset, backend=backend)


# ---------------------------------------------------------------------------
# Batched 5C+CH filtering (DESIGN.md §3): the separating-axis test runs as
# one padded einsum pass over the whole candidate batch.
# ---------------------------------------------------------------------------

def _sat_disjoint_batch(A: np.ndarray, B: np.ndarray) -> np.ndarray:
    """Vectorized separating-axis test: A, B [N, V, 2] (padded convex rings;
    padding must repeat a real vertex so extra edges are zero-length and the
    wrap-around edge stays the true closing edge). Returns [N] bool."""
    out = np.zeros(len(A), bool)
    for h0, h1 in ((A, B), (B, A)):
        edges = np.roll(h0, -1, axis=1) - h0
        normals = np.stack([-edges[..., 1], edges[..., 0]], axis=-1)  # [N,V,2]
        p0 = np.einsum("npc,nec->npe", h0, normals)
        p1 = np.einsum("npc,nec->npe", h1, normals)
        sep = ((p1.max(axis=1) < p0.min(axis=1))
               | (p1.min(axis=1) > p0.max(axis=1)))
        out |= sep.any(axis=1)
    return out


def _pad_hulls(store: FiveCCH, idx: np.ndarray):
    """Gather hulls ``idx`` into a padded [B, H, 2] array (repeat-last-vertex
    padding) plus the real vertex counts [B]."""
    idx = np.asarray(idx, np.int64)
    lo = store.hull_off[idx]
    counts = (store.hull_off[idx + 1] - lo).astype(np.int64)
    B = len(idx)
    H = int(max(1, counts.max() if B else 1))
    col = np.arange(H)[None, :]
    src = lo[:, None] + np.minimum(col, np.maximum(counts[:, None] - 1, 0))
    return store.hull_pts[src], counts


def fivecch_filter_batch(store_r: FiveCCH, store_s: FiveCCH,
                         pairs: np.ndarray) -> np.ndarray:
    """Vectorized 5C+CH filter; verdict-identical to
    :func:`fivecch_verdict_pair` per pair (TRUE_NEG / INDECISIVE only)."""
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    N = len(pairs)
    if N == 0:
        return np.zeros(0, np.int8)
    neg = _sat_disjoint_batch(store_r.pent[pairs[:, 0]],
                              store_s.pent[pairs[:, 1]])
    live = np.nonzero(~neg)[0]
    if len(live):
        ha, na = _pad_hulls(store_r, pairs[live, 0])
        hb, nb = _pad_hulls(store_s, pairs[live, 1])
        ok = (na >= 3) & (nb >= 3)      # degenerate hulls skip the CH stage
        hull_neg = _sat_disjoint_batch(ha, hb) & ok
        neg[live] |= hull_neg
    return np.where(neg, TRUE_NEG, INDECISIVE).astype(np.int8)
