"""5C+CH intermediate filter (Brinkhoff et al. [9]).

Conservative approximations applied in sequence: the minimum-bounding
5-corner convex polygon (realized as a 5-direction DOP: the intersection of
half-planes at five fixed orientations, whose corners we materialize), then
the exact convex hull. Both are conservative-only: they certify TRUE
negatives (approximations disjoint) but never true hits — matching the
paper's observation that 5C+CH detects 0% true hits (Fig. 13, Tables 13/16).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.join import INDECISIVE, TRUE_NEG

__all__ = ["FiveCCH", "build_5cch", "fivecch_verdict_pair", "convex_hull"]

# 5 fixed outward normals (72-degree steps)
_ANG = np.pi / 2 + 2 * np.pi * np.arange(5) / 5
_DIRS = np.stack([np.cos(_ANG), np.sin(_ANG)], axis=1)   # [5,2]

# Precompute corner solve matrices for adjacent direction pairs
_CORNER_INV = []
for _k in range(5):
    A = np.stack([_DIRS[_k], _DIRS[(_k + 1) % 5]])
    _CORNER_INV.append(np.linalg.inv(A))


@dataclass
class FiveCCH:
    pent: np.ndarray             # [P,5,2] pentagon corners (CCW)
    hull_off: np.ndarray         # [P+1]
    hull_pts: np.ndarray         # [sum_H, 2]

    def __len__(self):
        return len(self.pent)

    def hull(self, i: int) -> np.ndarray:
        return self.hull_pts[self.hull_off[i]: self.hull_off[i + 1]]

    def size_bytes(self) -> int:
        # 5 corner points per 5C + hull points, float32 pairs
        return 4 * 2 * 5 * len(self.pent) + 4 * 2 * len(self.hull_pts)


def convex_hull(points: np.ndarray) -> np.ndarray:
    """Andrew's monotone chain. points [N,2] -> hull [H,2] CCW."""
    pts = np.unique(np.asarray(points, np.float64), axis=0)
    if len(pts) <= 2:
        return pts
    order = np.lexsort((pts[:, 1], pts[:, 0]))
    pts = pts[order]

    def half(ps):
        out = []
        for p in ps:
            while len(out) >= 2:
                u = out[-1] - out[-2]
                w = p - out[-2]
                if u[0] * w[1] - u[1] * w[0] <= 0:
                    out.pop()
                else:
                    break
            out.append(p)
        return out

    lower = half(list(pts))
    upper = half(list(pts[::-1]))
    return np.asarray(lower[:-1] + upper[:-1])


def _pentagon(verts: np.ndarray) -> np.ndarray:
    """Corners of the 5-direction DOP enclosing ``verts``."""
    m = (verts @ _DIRS.T).max(axis=0)        # [5] support values
    corners = np.stack([
        _CORNER_INV[k] @ np.array([m[k], m[(k + 1) % 5]]) for k in range(5)
    ])
    return corners


def build_5cch(dataset) -> FiveCCH:
    P = len(dataset)
    pent = np.zeros((P, 5, 2))
    off = [0]; hulls = []
    for i in range(P):
        v = dataset.polygon(i)
        pent[i] = _pentagon(v)
        h = convex_hull(v)
        hulls.append(h)
        off.append(off[-1] + len(h))
    return FiveCCH(pent=pent,
                   hull_off=np.asarray(off, np.int64),
                   hull_pts=np.concatenate(hulls, axis=0))


def convex_disjoint(ha: np.ndarray, hb: np.ndarray) -> bool:
    """Separating-axis test for two convex polygons (CCW or CW)."""
    for h0, h1 in ((ha, hb), (hb, ha)):
        edges = np.roll(h0, -1, axis=0) - h0
        normals = np.stack([-edges[:, 1], edges[:, 0]], axis=1)
        p0 = h0 @ normals.T
        p1 = h1 @ normals.T
        sep = (p1.max(axis=0) < p0.min(axis=0)) | (p1.min(axis=0) > p0.max(axis=0))
        if bool(sep.any()):
            return True
    return False


def fivecch_verdict_pair(store_r: FiveCCH, i: int, store_s: FiveCCH, j: int) -> int:
    """5C stage first (cheap), then CH stage; TRUE_NEG or INDECISIVE only."""
    if convex_disjoint(store_r.pent[i], store_s.pent[j]):
        return TRUE_NEG
    ha, hb = store_r.hull(i), store_s.hull(j)
    if len(ha) >= 3 and len(hb) >= 3 and convex_disjoint(ha, hb):
        return TRUE_NEG
    return INDECISIVE
