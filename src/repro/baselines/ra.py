"""RA — the raster approximation of Zimbrao & de Souza [58] (paper §2).

Per-object grid over the MBR with at most K cells; cell side quantized to
``omega * 2^k`` with coordinates at multiples of the side, so any two RA
grids are hierarchically aligned and differ by a power-of-two scale. Cells
carry one of four classes: Empty / Weak (<=50%) / Strong (>50%) / Full,
assigned from exact coverage fractions. Pair filtering re-scales the finer
grid (2x2 combination) onto the coarser one and applies Table 1.

Combination caveat (faithful to the information RA stores): classes — not
fractions — are stored, so combined 2x2 classes use midpoint coverage
estimates (Empty=0, Weak=0.25, Strong=0.75, Full=1). To remain *sound*, an
estimated combination can only produce Weak/Strong labels; Full (resp.
Empty) requires all four children Full (resp. Empty). With that, Table 1
verdicts stay conservative and the filter never contradicts the geometry.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core import rasterize
from ..core.join import INDECISIVE, TRUE_HIT, TRUE_NEG
from ..core.rasterize import Extent

__all__ = ["RAStore", "build_ra", "ra_verdict_pair"]

EMPTY, WEAK, STRONG, FULL = 0, 1, 2, 3
_MID = np.array([0.0, 0.25, 0.75, 1.0])

# Table 1: does a shared cell certify intersection? yes=1 / no=-1 / maybe=0
_TABLE = np.zeros((4, 4), np.int8)
_TABLE[EMPTY, :] = -1; _TABLE[:, EMPTY] = -1
_TABLE[FULL, WEAK:] = 1; _TABLE[WEAK:, FULL] = 1
_TABLE[STRONG, STRONG] = 1
_TABLE[WEAK, WEAK] = 0; _TABLE[WEAK, STRONG] = 0; _TABLE[STRONG, WEAK] = 0


@dataclass
class RAStore:
    omega: float                 # unit cell side
    k: np.ndarray                # [P] scale exponent: cell side = omega * 2^k
    origin: np.ndarray           # [P,2] grid origin (multiple of side)
    shape: np.ndarray            # [P,2] (nx, ny) cells
    cells: list[np.ndarray]      # per object: [ny, nx] int8 class grid

    def __len__(self):
        return len(self.cells)

    def size_bytes(self) -> int:
        # 2 bits/cell packed (4 classes) + per-object header
        return sum((c.size + 3) // 4 for c in self.cells) + 24 * len(self.cells)


def build_ra(dataset, max_cells: int = 750, omega: float = 1.0 / (1 << 16)) -> RAStore:
    P = len(dataset)
    ks = np.zeros(P, np.int64)
    origins = np.zeros((P, 2))
    shapes = np.zeros((P, 2), np.int64)
    grids: list[np.ndarray] = []
    for i in range(P):
        v = dataset.polygon(i)
        mbr = dataset.mbrs[i]
        w = mbr[2] - mbr[0]; h = mbr[3] - mbr[1]
        # smallest k with cell count <= max_cells
        k = 0
        while True:
            side = omega * (1 << k)
            nx = int(np.floor(mbr[2] / side)) - int(np.floor(mbr[0] / side)) + 1
            ny = int(np.floor(mbr[3] / side)) - int(np.floor(mbr[1] / side)) + 1
            if nx * ny <= max_cells or side > 1.0:
                break
            k += 1
        side = omega * (1 << k)
        ox = np.floor(mbr[0] / side) * side
        oy = np.floor(mbr[1] / side) * side
        nx = int(np.floor(mbr[2] / side)) - int(np.floor(mbr[0] / side)) + 1
        ny = int(np.floor(mbr[3] / side)) - int(np.floor(mbr[1] / side)) + 1
        # coverage fractions for all cells in the window
        cxs = np.arange(nx); cys = np.arange(ny)
        CX, CY = np.meshgrid(cxs, cys, indexing="xy")
        cells = np.stack([CX.ravel(), CY.ravel()], axis=1)
        ext = Extent(ox, oy, side)  # one-cell extent trick: order 0 per cell
        frac = rasterize.coverage_fractions(v, len(v), cells, 0, ext)
        grid = np.full(nx * ny, EMPTY, np.int8)
        grid[(frac > 0) & (frac <= 0.5)] = WEAK
        grid[(frac > 0.5) & (frac < 1.0 - 1e-12)] = STRONG
        grid[frac >= 1.0 - 1e-12] = FULL
        ks[i] = k
        origins[i] = (ox, oy)
        shapes[i] = (nx, ny)
        grids.append(grid.reshape(ny, nx))
    return RAStore(omega=omega, k=ks, origin=origins, shape=shapes, cells=grids)


def _upscale_to(store: RAStore, i: int, k_to: int):
    """Combine 2x2 blocks until object i's grid reaches scale k_to.
    Returns (origin, grid) at scale k_to with sound class combination."""
    grid = store.cells[i]
    k = int(store.k[i])
    ox, oy = store.origin[i]
    side = store.omega * (1 << k)
    while k < k_to:
        ny, nx = grid.shape
        # align origin to the parent grid
        gx = int(np.floor(round(ox / side)))  # integer cell coords at scale k
        gy = int(np.floor(round(oy / side)))
        pad_l = gx & 1
        pad_b = gy & 1
        pad_r = (nx + pad_l) & 1
        pad_t = (ny + pad_b) & 1
        g = np.pad(grid, ((pad_b, pad_t), (pad_l, pad_r)), constant_values=EMPTY)
        # coverage LOWER bounds per class keep the combination sound: a
        # parent may be labeled STRONG only when its true coverage provably
        # exceeds 50% (Table 1's strong-strong => hit rule demands it).
        lo_tab = np.array([0.0, 0.0, 0.5, 1.0])   # EMPTY WEAK STRONG FULL
        lo = (lo_tab[g[0::2, 0::2]] + lo_tab[g[1::2, 0::2]]
              + lo_tab[g[0::2, 1::2]] + lo_tab[g[1::2, 1::2]]) / 4.0
        allfull = ((g[0::2, 0::2] == FULL) & (g[1::2, 0::2] == FULL)
                   & (g[0::2, 1::2] == FULL) & (g[1::2, 1::2] == FULL))
        allempty = ((g[0::2, 0::2] == EMPTY) & (g[1::2, 0::2] == EMPTY)
                    & (g[0::2, 1::2] == EMPTY) & (g[1::2, 1::2] == EMPTY))
        out = np.where(lo > 0.5, STRONG, WEAK).astype(np.int8)
        out[allfull] = FULL
        out[allempty] = EMPTY
        grid = out
        ox = (gx - pad_l) * side
        oy = (gy - pad_b) * side
        k += 1
        side *= 2
    return (ox, oy), grid


def ra_verdict_pair(store_r: RAStore, i: int, store_s: RAStore, j: int) -> int:
    """Re-scale to the coarser grid, overlay, and apply Table 1."""
    k = max(int(store_r.k[i]), int(store_s.k[j]))
    (oxr, oyr), gr = _upscale_to(store_r, i, k)
    (oxs, oys), gs = _upscale_to(store_s, j, k)
    side = store_r.omega * (1 << k)
    # integer cell coordinates of each grid origin (aligned by construction)
    rx0 = int(round(oxr / side)); ry0 = int(round(oyr / side))
    sx0 = int(round(oxs / side)); sy0 = int(round(oys / side))
    x0 = max(rx0, sx0); y0 = max(ry0, sy0)
    x1 = min(rx0 + gr.shape[1], sx0 + gs.shape[1])
    y1 = min(ry0 + gr.shape[0], sy0 + gs.shape[0])
    if x0 >= x1 or y0 >= y1:
        return TRUE_NEG
    sub_r = gr[y0 - ry0: y1 - ry0, x0 - rx0: x1 - rx0]
    sub_s = gs[y0 - sy0: y1 - sy0, x0 - sx0: x1 - sx0]
    t = _TABLE[sub_r, sub_s]
    if bool((t == 1).any()):
        return TRUE_HIT
    if bool((t == 0).any()):
        return INDECISIVE
    return TRUE_NEG
