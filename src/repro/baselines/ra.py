"""RA — the raster approximation of Zimbrao & de Souza [58] (paper §2).

Per-object grid over the MBR with at most K cells; cell side quantized to
``omega * 2^k`` with coordinates at multiples of the side, so any two RA
grids are hierarchically aligned and differ by a power-of-two scale. Cells
carry one of four classes: Empty / Weak (<=50%) / Strong (>50%) / Full,
assigned from exact coverage fractions. Pair filtering re-scales the finer
grid (2x2 combination) onto the coarser one and applies Table 1.

Combination caveat (faithful to the information RA stores): classes — not
fractions — are stored, so combined 2x2 classes use midpoint coverage
estimates (Empty=0, Weak=0.25, Strong=0.75, Full=1). To remain *sound*, an
estimated combination can only produce Weak/Strong labels; Full (resp.
Empty) requires all four children Full (resp. Empty). With that, Table 1
verdicts stay conservative and the filter never contradicts the geometry.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core import rasterize
from ..core.join import INDECISIVE, TRUE_HIT, TRUE_NEG
from ..core.rasterize import Extent

__all__ = ["RAStore", "build_ra", "build_ra_lines", "ra_verdict_pair",
           "ra_filter_batch", "ra_within_verdict_pair", "ra_within_batch"]

EMPTY, WEAK, STRONG, FULL = 0, 1, 2, 3
_MID = np.array([0.0, 0.25, 0.75, 1.0])

# Table 1: does a shared cell certify intersection? yes=1 / no=-1 / maybe=0
_TABLE = np.zeros((4, 4), np.int8)
_TABLE[EMPTY, :] = -1; _TABLE[:, EMPTY] = -1
_TABLE[FULL, WEAK:] = 1; _TABLE[WEAK:, FULL] = 1
_TABLE[STRONG, STRONG] = 1
_TABLE[WEAK, WEAK] = 0; _TABLE[WEAK, STRONG] = 0; _TABLE[STRONG, WEAK] = 0


@dataclass
class RAStore:
    omega: float                 # unit cell side
    k: np.ndarray                # [P] scale exponent: cell side = omega * 2^k
    origin: np.ndarray           # [P,2] grid origin (multiple of side)
    shape: np.ndarray            # [P,2] (nx, ny) cells
    cells: list[np.ndarray]      # per object: [ny, nx] int8 class grid

    def __len__(self):
        return len(self.cells)

    def size_bytes(self) -> int:
        # 2 bits/cell packed (4 classes) + per-object header
        return sum((c.size + 3) // 4 for c in self.cells) + 24 * len(self.cells)


def _fit_grid(mbr, max_cells: int, omega: float):
    """Smallest aligned grid scale with cell count <= max_cells:
    (k, side, ox, oy, nx, ny)."""
    k = 0
    while True:
        side = omega * (1 << k)
        nx = int(np.floor(mbr[2] / side)) - int(np.floor(mbr[0] / side)) + 1
        ny = int(np.floor(mbr[3] / side)) - int(np.floor(mbr[1] / side)) + 1
        if nx * ny <= max_cells or side > 1.0:
            break
        k += 1
    ox = np.floor(mbr[0] / side) * side
    oy = np.floor(mbr[1] / side) * side
    return k, side, ox, oy, nx, ny


def _fit_grid_multi(mbrs: np.ndarray, max_cells: int, omega: float):
    """Vectorized :func:`_fit_grid` over all objects: escalate the scale of
    the not-yet-fitting subset until every grid has <= max_cells cells.
    Returns (k [P], side [P], ox [P], oy [P], nx [P], ny [P])."""
    mbrs = np.asarray(mbrs, np.float64)
    P = len(mbrs)
    k = np.zeros(P, np.int64)
    nx = np.zeros(P, np.int64)
    ny = np.zeros(P, np.int64)
    todo = np.arange(P)
    while len(todo):
        side = omega * np.exp2(k[todo])
        cnx = (np.floor(mbrs[todo, 2] / side).astype(np.int64)
               - np.floor(mbrs[todo, 0] / side).astype(np.int64) + 1)
        cny = (np.floor(mbrs[todo, 3] / side).astype(np.int64)
               - np.floor(mbrs[todo, 1] / side).astype(np.int64) + 1)
        done = (cnx * cny <= max_cells) | (side > 1.0)
        fin = todo[done]
        nx[fin] = cnx[done]
        ny[fin] = cny[done]
        todo = todo[~done]
        k[todo] += 1
    side = omega * np.exp2(k)
    ox = np.floor(mbrs[:, 0] / side) * side
    oy = np.floor(mbrs[:, 1] / side) * side
    return k, side, ox, oy, nx, ny


def _grids_from_classes(cls_flat, coff, nx, ny):
    return [cls_flat[coff[i]: coff[i + 1]].reshape(ny[i], nx[i])
            for i in range(len(nx))]


def build_ra(dataset, max_cells: int = 750, omega: float = 1.0 / (1 << 16),
             backend: str = "numpy") -> RAStore:
    """Build the RA store. ``backend``: 'numpy' | 'jnp' evaluate the coverage
    fractions of ALL (object x window-cell) rows in one padded
    Sutherland–Hodgman pass (DESIGN.md §6); 'sequential' is the per-object
    reference loop with per-cell clipping."""
    P = len(dataset)
    if backend == "sequential":
        ks = np.zeros(P, np.int64)
        origins = np.zeros((P, 2))
        shapes = np.zeros((P, 2), np.int64)
        grids: list[np.ndarray] = []
        for i in range(P):
            v = dataset.polygon(i)
            k, side, ox, oy, nx, ny = _fit_grid(dataset.mbrs[i], max_cells,
                                                omega)
            # coverage fractions for all cells in the window
            cxs = np.arange(nx); cys = np.arange(ny)
            CX, CY = np.meshgrid(cxs, cys, indexing="xy")
            cells = np.stack([CX.ravel(), CY.ravel()], axis=1)
            ext = Extent(ox, oy, side)  # one-cell extent trick: order 0/cell
            frac = rasterize.coverage_fractions(v, len(v), cells, 0, ext)
            grid = np.full(nx * ny, EMPTY, np.int8)
            grid[(frac > 0) & (frac <= 0.5)] = WEAK
            grid[(frac > 0.5) & (frac < 1.0 - 1e-12)] = STRONG
            grid[frac >= 1.0 - 1e-12] = FULL
            ks[i] = k
            origins[i] = (ox, oy)
            shapes[i] = (nx, ny)
            grids.append(grid.reshape(ny, nx))
        return RAStore(omega=omega, k=ks, origin=origins, shape=shapes,
                       cells=grids)

    from ..core import geometry
    k, side, ox, oy, nx, ny = _fit_grid_multi(dataset.mbrs, max_cells, omega)
    ncell = nx * ny
    coff = np.concatenate([[0], np.cumsum(ncell)])
    cls = np.full(coff[-1], EMPTY, np.int8)
    # object slices bound the flat (object x window-cell) transients — the
    # per-object memory profile stays O(chunk), not O(dataset)
    cells_per_chunk = 1 << 22
    p0 = 0
    while p0 < P:
        p1 = int(np.searchsorted(coff, coff[p0] + cells_per_chunk, "right"))
        p1 = max(p1 - 1, p0 + 1)
        pid = np.repeat(np.arange(p0, p1), ncell[p0:p1])
        t = np.arange(coff[p1] - coff[p0]) - (coff[p0:p1] - coff[p0])[pid - p0]
        cx = t % nx[pid]
        cy = t // nx[pid]
        sp = side[pid]
        boxes = np.stack([ox[pid] + cx * sp, oy[pid] + cy * sp,
                          ox[pid] + (cx + 1) * sp, oy[pid] + (cy + 1) * sp],
                         axis=1)
        areas = geometry.box_clip_areas_rows(
            dataset.verts, dataset.nverts, pid, boxes, backend=backend)
        frac = np.clip(areas / (sp * sp), 0.0, 1.0)
        seg = cls[coff[p0]: coff[p1]]
        seg[(frac > 0) & (frac <= 0.5)] = WEAK
        seg[(frac > 0.5) & (frac < 1.0 - 1e-12)] = STRONG
        seg[frac >= 1.0 - 1e-12] = FULL
        p0 = p1
    return RAStore(omega=omega, k=k, origin=np.stack([ox, oy], axis=1),
                   shape=np.stack([nx, ny], axis=1),
                   cells=_grids_from_classes(cls, coff, nx, ny))


def _upscale_to(store: RAStore, i: int, k_to: int):
    """Combine 2x2 blocks until object i's grid reaches scale k_to.
    Returns (origin, grid) at scale k_to with sound class combination."""
    grid = store.cells[i]
    k = int(store.k[i])
    ox, oy = store.origin[i]
    side = store.omega * (1 << k)
    while k < k_to:
        ny, nx = grid.shape
        # align origin to the parent grid
        gx = int(np.floor(round(ox / side)))  # integer cell coords at scale k
        gy = int(np.floor(round(oy / side)))
        pad_l = gx & 1
        pad_b = gy & 1
        pad_r = (nx + pad_l) & 1
        pad_t = (ny + pad_b) & 1
        g = np.pad(grid, ((pad_b, pad_t), (pad_l, pad_r)), constant_values=EMPTY)
        # coverage LOWER bounds per class keep the combination sound: a
        # parent may be labeled STRONG only when its true coverage provably
        # exceeds 50% (Table 1's strong-strong => hit rule demands it).
        lo_tab = np.array([0.0, 0.0, 0.5, 1.0])   # EMPTY WEAK STRONG FULL
        lo = (lo_tab[g[0::2, 0::2]] + lo_tab[g[1::2, 0::2]]
              + lo_tab[g[0::2, 1::2]] + lo_tab[g[1::2, 1::2]]) / 4.0
        allfull = ((g[0::2, 0::2] == FULL) & (g[1::2, 0::2] == FULL)
                   & (g[0::2, 1::2] == FULL) & (g[1::2, 1::2] == FULL))
        allempty = ((g[0::2, 0::2] == EMPTY) & (g[1::2, 0::2] == EMPTY)
                    & (g[0::2, 1::2] == EMPTY) & (g[1::2, 1::2] == EMPTY))
        out = np.where(lo > 0.5, STRONG, WEAK).astype(np.int8)
        out[allfull] = FULL
        out[allempty] = EMPTY
        grid = out
        ox = (gx - pad_l) * side
        oy = (gy - pad_b) * side
        k += 1
        side *= 2
    return (ox, oy), grid


def build_ra_lines(dataset, max_cells: int = 750,
                   omega: float = 1.0 / (1 << 16),
                   backend: str = "numpy") -> RAStore:
    """RA store for open linestrings: cells crossed by the chain are Weak
    (zero area => never Strong/Full), the rest Empty. Table 1 still applies:
    Weak x Full certifies a hit, Weak x Weak/Strong stays indecisive."""
    P = len(dataset)
    if backend == "sequential":
        ks = np.zeros(P, np.int64)
        origins = np.zeros((P, 2))
        shapes = np.zeros((P, 2), np.int64)
        grids: list[np.ndarray] = []
        for i in range(P):
            v = dataset.polygon(i)
            k, side, ox, oy, nx, ny = _fit_grid(dataset.mbrs[i], max_cells,
                                                omega)
            # rasterize the chain on a power-of-two grid covering the window
            n_ord = max(1, int(np.ceil(np.log2(max(nx, ny)))))
            ext = Extent(ox, oy, side * (1 << n_ord))
            cells = rasterize.dda_partial_cells(v, len(v), n_ord, ext,
                                                closed=False)
            grid = np.full((ny, nx), EMPTY, np.int8)
            if len(cells):
                keep = (cells[:, 0] < nx) & (cells[:, 1] < ny)
                grid[cells[keep, 1], cells[keep, 0]] = WEAK
            ks[i] = k
            origins[i] = (ox, oy)
            shapes[i] = (nx, ny)
            grids.append(grid)
        return RAStore(omega=omega, k=ks, origin=origins, shape=shapes,
                       cells=grids)

    # batched: one flat clipped traversal over all chains, each in its own
    # per-object grid frame (per-edge grid bound G = 2^n_ord of its object)
    from ..core.rasterize import clip_segments_to_grid, dda_traverse
    k, side, ox, oy, nx, ny = _fit_grid_multi(dataset.mbrs, max_cells, omega)
    n_ord = np.maximum(
        1, np.ceil(np.log2(np.maximum(nx, ny).astype(np.float64)))
    ).astype(np.int64)
    G = (np.int64(1) << n_ord)
    # grid coords mirror Extent(ox, oy, side * G).cell_size(n_ord) == side
    h = (side * G) / G
    verts = np.asarray(dataset.verts, np.float64)
    nverts = np.asarray(dataset.nverts, np.int64)
    V = verts.shape[1]
    idx = np.arange(V)[None, :]
    edge_valid = idx < nverts[:, None] - 1
    pe, ve = np.nonzero(edge_valid)
    org = np.stack([ox, oy], axis=1)
    a = (verts[pe, ve] - org[pe]) / h[pe, None]
    b = (verts[pe, np.minimum(ve + 1, V - 1)] - org[pe]) / h[pe, None]
    a_c, b_c, keep = clip_segments_to_grid(a, b, G[pe].astype(np.float64))
    pe = pe[keep]
    eid, cells = dda_traverse(a_c[keep], b_c[keep], G[pe])
    pid = pe[eid]
    ncell = nx * ny
    coff = np.concatenate([[0], np.cumsum(ncell)])
    cls = np.full(coff[-1], EMPTY, np.int8)
    inb = (cells[:, 0] < nx[pid]) & (cells[:, 1] < ny[pid])
    cls[coff[:-1][pid[inb]] + cells[inb, 1] * nx[pid[inb]]
        + cells[inb, 0]] = WEAK
    return RAStore(omega=omega, k=k, origin=org,
                   shape=np.stack([nx, ny], axis=1),
                   cells=_grids_from_classes(cls, coff, nx, ny))


# ---------------------------------------------------------------------------
# Batched RA filtering (DESIGN.md §3): per-object pyramids are memoized, the
# per-pair overlay + Table-1 lookup is one padded vectorized gather.
# ---------------------------------------------------------------------------

def _upscaled(store: RAStore, i: int, k: int, cache: dict | None):
    """Memoized :func:`_upscale_to`: (int origin x/y at scale k, flat grid,
    nx, ny)."""
    key = (i, k)
    if cache is not None and key in cache:
        return cache[key]
    (ox, oy), grid = _upscale_to(store, i, k)
    side = store.omega * (1 << k)
    entry = (int(round(ox / side)), int(round(oy / side)),
             np.ascontiguousarray(grid).ravel(), grid.shape[1], grid.shape[0])
    if cache is not None:
        cache[key] = entry
    return entry


def _pair_grids(store_r, store_s, pairs, cache_r, cache_s):
    """Upscale both sides of every pair to the pair's coarser scale and
    return flat-concatenated grids plus per-pair geometry arrays.

    Per-pair work is a vectorized gather over the *unique* (object, scale)
    combinations of the batch — Python touches each combination once (and
    the ``cache`` dict memoizes pyramids across batches and predicates), so
    a T1xT2-scale batch costs O(unique objects), not O(pairs).
    """
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    kk = np.maximum(store_r.k[pairs[:, 0]], store_s.k[pairs[:, 1]]).astype(np.int64)

    def side_arrays(store, idx, cache):
        # composite (object, scale) keys; scales are bounded (cell side
        # stops growing past 1.0, well under 2^32)
        keys = (idx.astype(np.int64) << 32) | kk
        ukeys, inv = np.unique(keys, return_inverse=True)
        ents = [_upscaled(store, int(key >> 32), int(key & 0xFFFFFFFF), cache)
                for key in ukeys]
        lens = np.asarray([len(e[2]) for e in ents], np.int64)
        ubase = np.zeros(len(ents), np.int64)
        np.cumsum(lens[:-1], out=ubase[1:])
        flat_all = (np.concatenate([e[2] for e in ents]) if ents
                    else np.zeros(0, np.int8))
        ux0 = np.asarray([e[0] for e in ents], np.int64)
        uy0 = np.asarray([e[1] for e in ents], np.int64)
        unx = np.asarray([e[3] for e in ents], np.int64)
        uny = np.asarray([e[4] for e in ents], np.int64)
        return (flat_all, ux0[inv], uy0[inv], ubase[inv], unx[inv], uny[inv])

    r = side_arrays(store_r, pairs[:, 0], cache_r)
    s = side_arrays(store_s, pairs[:, 1], cache_s)
    return kk, r, s


def ra_filter_batch(store_r: RAStore, store_s: RAStore, pairs: np.ndarray,
                    cache_r: dict | None = None, cache_s: dict | None = None,
                    chunk_elems: int = 1 << 24) -> np.ndarray:
    """Vectorized RA intersection filter; verdict-identical to
    :func:`ra_verdict_pair` per pair."""
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    N = len(pairs)
    if N == 0:
        return np.zeros(0, np.int8)
    _, (fr, rx0, ry0, rb, rnx, rny), (fs, sx0, sy0, sb, snx, sny) = \
        _pair_grids(store_r, store_s, pairs, cache_r, cache_s)
    x0 = np.maximum(rx0, sx0); y0 = np.maximum(ry0, sy0)
    x1 = np.minimum(rx0 + rnx, sx0 + snx)
    y1 = np.minimum(ry0 + rny, sy0 + sny)
    ww = np.maximum(x1 - x0, 0); wh = np.maximum(y1 - y0, 0)
    out = np.full(N, TRUE_NEG, np.int8)
    live = np.nonzero((ww > 0) & (wh > 0))[0]
    i0 = 0
    while i0 < len(live):
        Hm = int(wh[live[i0:]].max()); Wm = int(ww[live[i0:]].max())
        rows = max(1, int(chunk_elems // max(1, Hm * Wm)))
        sel = live[i0: i0 + rows]
        Hm = int(wh[sel].max()); Wm = int(ww[sel].max())
        yy = np.arange(Hm)[None, :, None]
        xx = np.arange(Wm)[None, None, :]
        valid = (yy < wh[sel, None, None]) & (xx < ww[sel, None, None])

        def gather(flat, bs, gx0, gy0, nx):
            idx = (bs[sel, None, None]
                   + (y0[sel, None, None] - gy0[sel, None, None] + yy) * nx[sel, None, None]
                   + (x0[sel, None, None] - gx0[sel, None, None] + xx))
            return np.where(valid,
                            flat[np.clip(idx, 0, max(len(flat) - 1, 0))], EMPTY)

        cr = gather(fr, rb, rx0, ry0, rnx)
        cs = gather(fs, sb, sx0, sy0, snx)
        t = _TABLE[cr, cs]
        hit = np.any((t == 1) & valid, axis=(1, 2))
        maybe = np.any((t == 0) & valid, axis=(1, 2))
        out[sel] = np.where(hit, TRUE_HIT,
                            np.where(maybe, INDECISIVE, TRUE_NEG))
        i0 += len(sel)
    return out


def ra_within_verdict_pair(store_r: RAStore, i: int, store_s: RAStore,
                           j: int) -> int:
    """RA within filter (r within s?), sequential reference.

    Sound rules at the pair's coarser scale k: any non-Empty r cell that is
    Empty in s (or outside s's grid) kills the pair; r Full requires s Full;
    r Strong vs s Weak kills only when s is at its native scale (an upscaled
    Weak is not a <=50% upper bound). TRUE_HIT iff every non-Empty r cell is
    Full in s. Never contradicts the geometry (class combination is
    conservative, see :func:`_upscale_to`).
    """
    k = max(int(store_r.k[i]), int(store_s.k[j]))
    (oxr, oyr), gr = _upscale_to(store_r, i, k)
    (oxs, oys), gs = _upscale_to(store_s, j, k)
    side = store_r.omega * (1 << k)
    rx0 = int(round(oxr / side)); ry0 = int(round(oyr / side))
    sx0 = int(round(oxs / side)); sy0 = int(round(oys / side))
    s_native = k == int(store_s.k[j])
    all_full = True
    nonempty = False
    for y in range(gr.shape[0]):
        for x in range(gr.shape[1]):
            cr = gr[y, x]
            if cr == EMPTY:
                continue
            nonempty = True
            gx = rx0 + x - sx0
            gy = ry0 + y - sy0
            if gx < 0 or gy < 0 or gx >= gs.shape[1] or gy >= gs.shape[0]:
                return TRUE_NEG
            cs = gs[gy, gx]
            if cs == EMPTY:
                return TRUE_NEG
            if cr == FULL and cs != FULL:
                return TRUE_NEG
            if s_native and cr == STRONG and cs == WEAK:
                return TRUE_NEG
            if cs != FULL:
                all_full = False
    if not nonempty:
        return TRUE_HIT
    return TRUE_HIT if all_full else INDECISIVE


def ra_within_batch(store_r: RAStore, store_s: RAStore, pairs: np.ndarray,
                    cache_r: dict | None = None, cache_s: dict | None = None,
                    chunk_elems: int = 1 << 24) -> np.ndarray:
    """Vectorized RA within filter; verdict-identical to
    :func:`ra_within_verdict_pair` per pair."""
    pairs = np.asarray(pairs, np.int64).reshape(-1, 2)
    N = len(pairs)
    if N == 0:
        return np.zeros(0, np.int8)
    kk, (fr, rx0, ry0, rb, rnx, rny), (fs, sx0, sy0, sb, snx, sny) = \
        _pair_grids(store_r, store_s, pairs, cache_r, cache_s)
    s_native = kk == store_s.k[pairs[:, 1]].astype(np.int64)
    out = np.empty(N, np.int8)
    i0 = 0
    order = np.arange(N)
    while i0 < N:
        Hm = int(rny[order[i0:]].max()); Wm = int(rnx[order[i0:]].max())
        rows = max(1, int(chunk_elems // max(1, Hm * Wm)))
        sel = order[i0: i0 + rows]
        Hm = int(rny[sel].max()); Wm = int(rnx[sel].max())
        yy = np.arange(Hm)[None, :, None]
        xx = np.arange(Wm)[None, None, :]
        valid = (yy < rny[sel, None, None]) & (xx < rnx[sel, None, None])
        idx_r = rb[sel, None, None] + yy * rnx[sel, None, None] + xx
        cr = np.where(valid, fr[np.clip(idx_r, 0, max(len(fr) - 1, 0))], EMPTY)
        gx = rx0[sel, None, None] + xx - sx0[sel, None, None]
        gy = ry0[sel, None, None] + yy - sy0[sel, None, None]
        inside = ((gx >= 0) & (gy >= 0) & (gx < snx[sel, None, None])
                  & (gy < sny[sel, None, None]))
        idx_s = sb[sel, None, None] + gy * snx[sel, None, None] + gx
        cs = np.where(valid & inside,
                      fs[np.clip(idx_s, 0, max(len(fs) - 1, 0))], EMPTY)
        ne = valid & (cr != EMPTY)
        neg_cell = ne & ((~inside) | (cs == EMPTY)
                         | ((cr == FULL) & (cs != FULL))
                         | (s_native[sel, None, None]
                            & (cr == STRONG) & (cs == WEAK)))
        notfull = ne & (cs != FULL)
        neg = np.any(neg_cell, axis=(1, 2))
        any_ne = np.any(ne, axis=(1, 2))
        nf = np.any(notfull, axis=(1, 2))
        out[sel] = np.where(neg, TRUE_NEG,
                            np.where(~any_ne | ~nf, TRUE_HIT, INDECISIVE))
        i0 += len(sel)
    return out


def ra_verdict_pair(store_r: RAStore, i: int, store_s: RAStore, j: int) -> int:
    """Re-scale to the coarser grid, overlay, and apply Table 1."""
    k = max(int(store_r.k[i]), int(store_s.k[j]))
    (oxr, oyr), gr = _upscale_to(store_r, i, k)
    (oxs, oys), gs = _upscale_to(store_s, j, k)
    side = store_r.omega * (1 << k)
    # integer cell coordinates of each grid origin (aligned by construction)
    rx0 = int(round(oxr / side)); ry0 = int(round(oyr / side))
    sx0 = int(round(oxs / side)); sy0 = int(round(oys / side))
    x0 = max(rx0, sx0); y0 = max(ry0, sy0)
    x1 = min(rx0 + gr.shape[1], sx0 + gs.shape[1])
    y1 = min(ry0 + gr.shape[0], sy0 + gs.shape[0])
    if x0 >= x1 or y0 >= y1:
        return TRUE_NEG
    sub_r = gr[y0 - ry0: y1 - ry0, x0 - rx0: x1 - rx0]
    sub_s = gs[y0 - sy0: y1 - sy0, x0 - sx0: x1 - sx0]
    t = _TABLE[sub_r, sub_s]
    if bool((t == 1).any()):
        return TRUE_HIT
    if bool((t == 0).any()):
        return INDECISIVE
    return TRUE_NEG
