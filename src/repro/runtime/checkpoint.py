"""Fault-tolerant checkpointing: sharded-to-host npy shards + manifest,
atomic directory commit, async save, crc32 integrity, keep-last-K GC,
restore with arbitrary re-sharding (elastic restarts).

Format:
    <dir>/step_<N>.tmp/...   (in-flight write, never read)
    <dir>/step_<N>/manifest.json   {step, leaves: {name: {shape, dtype,
                                    crc32}}, time, extra}
    <dir>/step_<N>/<leaf>.npy
    <dir>/LATEST               (text file, committed last)

Leaves are addressed by their pytree key-path string, so any tree of arrays
(params, optimizer state, data-pipeline cursors, partition progress) can be
checkpointed. Restore returns host numpy arrays — the caller device_puts
them under the *current* mesh's shardings, which is exactly what an elastic
restart with a different device count needs.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib

import jax
import numpy as np

__all__ = ["CheckpointManager", "tree_to_flat", "flat_to_tree"]


def _path_str(path) -> str:
    out = []
    for k in path:
        out.append(str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k)))))
    return "/".join(out)


def tree_to_flat(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_path_str(path)] = np.asarray(jax.device_get(leaf))
    return flat


def flat_to_tree(flat: dict, like):
    leaves, _ = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in leaves:
        key = _path_str(path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        out.append(arr)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ---------------------------------------------------------------- save
    def save(self, step: int, tree, extra: dict | None = None,
             block: bool = False):
        """Snapshot to host, then write (async by default)."""
        flat = tree_to_flat(tree)   # device->host copy happens HERE (sync)
        self.wait()
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, extra or {}))
            self._thread.start()
        else:
            self._write(step, flat, extra or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: dict, extra: dict):
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "time": time.time(), "extra": extra,
                    "leaves": {}}
        for name, arr in flat.items():
            fn = name.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fn), arr)
            manifest["leaves"][name] = {
                "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)                       # atomic commit
        with open(os.path.join(self.dir, "LATEST.tmp"), "w") as f:
            f.write(str(step))
        os.replace(os.path.join(self.dir, "LATEST.tmp"),
                   os.path.join(self.dir, "LATEST"))
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp") \
                    and os.path.exists(os.path.join(self.dir, d, "manifest.json")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        latest = os.path.join(self.dir, "LATEST")
        if os.path.exists(latest):
            s = int(open(latest).read().strip())
            if os.path.exists(os.path.join(self.dir, f"step_{s}",
                                           "manifest.json")):
                return s
        steps = self.all_steps()   # fall back: scan (LATEST lost/corrupt)
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, verify: bool = True):
        """Returns (step, flat dict of numpy arrays, extra) or None."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None
        d = os.path.join(self.dir, f"step_{step}")
        manifest = json.load(open(os.path.join(d, "manifest.json")))
        flat = {}
        for name, meta in manifest["leaves"].items():
            arr = np.load(os.path.join(d, meta["file"]))
            if verify:
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != meta["crc32"]:
                    raise IOError(f"checksum mismatch in {name} @ step {step}")
            flat[name] = arr
        return manifest["step"], flat, manifest.get("extra", {})

    def restore_tree(self, like, step: int | None = None):
        """Restore into the structure of ``like`` (host numpy leaves)."""
        res = self.restore(step)
        if res is None:
            return None
        step, flat, extra = res
        leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
        arrs = []
        for path, leaf in leaves:
            key = _path_str(path)
            if key not in flat:
                raise KeyError(f"checkpoint missing leaf {key}")
            arrs.append(flat[key])
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), arrs)
        return step, tree, extra
