"""Elastic scaling + straggler mitigation utilities.

``remesh_tree`` re-lays a host (numpy) tree onto a NEW mesh — the core of an
elastic restart: after node loss the launcher rebuilds a smaller mesh,
restores the latest checkpoint (host arrays are global, so shardings of the
dead mesh are irrelevant) and device_puts under the new mesh's specs.

``StragglerMonitor`` tracks per-step wall times with an EMA and flags steps
exceeding ``threshold``x the running mean — on a real cluster the launcher
re-dispatches the slow host's shard / excludes the host on repeat offenses;
here it drives logging and the work-stealing partition queue of the
distributed spatial join.
"""
from __future__ import annotations

import time

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

__all__ = ["remesh_tree", "make_mesh_from_devices", "StragglerMonitor",
           "WorkQueue"]


def make_mesh_from_devices(devices, n_model: int, axis_names=("data", "model")):
    """Largest (data, model) mesh buildable from surviving devices."""
    n = len(devices)
    n_model = min(n_model, n)
    n_data = n // n_model
    devs = np.asarray(devices[: n_data * n_model]).reshape(n_data, n_model)
    return Mesh(devs, axis_names)


def remesh_tree(host_tree, mesh: Mesh, spec_tree):
    """device_put a host tree under ``mesh`` with PartitionSpec tree."""
    return jax.tree.map(
        lambda arr, spec: jax.device_put(arr, NamedSharding(mesh, spec)),
        host_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, (np.ndarray, jax.Array)))


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, ema: float = 0.9):
        self.threshold = threshold
        self.ema_coef = ema
        self.mean = None
        self.flagged: list[tuple[int, float]] = []
        self._t0 = None
        self.step_idx = 0

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> bool:
        """Record a step; returns True if it was a straggler."""
        dt = time.perf_counter() - self._t0
        slow = self.mean is not None and dt > self.threshold * self.mean
        self.mean = dt if self.mean is None else \
            self.ema_coef * self.mean + (1 - self.ema_coef) * dt
        if slow:
            self.flagged.append((self.step_idx, dt))
        self.step_idx += 1
        return slow


class WorkQueue:
    """Work-stealing queue over join partitions (straggler mitigation for
    the distributed spatial join): items are leased with a deadline; expired
    leases return to the queue so a healthy worker re-runs them. Results are
    idempotent (pure filter verdicts), so double-execution is safe."""

    def __init__(self, items, lease_seconds: float = 60.0):
        self.pending = list(items)
        self.leases: dict[object, float] = {}
        self.done: set = set()
        self.lease_seconds = lease_seconds

    def acquire(self):
        now = time.time()
        expired = [k for k, t in self.leases.items() if t < now]
        for k in expired:
            del self.leases[k]
            self.pending.append(k)
        if not self.pending:
            return None
        item = self.pending.pop(0)
        self.leases[item] = now + self.lease_seconds
        return item

    def complete(self, item):
        self.leases.pop(item, None)
        self.done.add(item)

    @property
    def finished(self) -> bool:
        return not self.pending and not self.leases
