from .checkpoint import CheckpointManager  # noqa: F401
from .elastic import remesh_tree  # noqa: F401
