"""Capacity-based top-k Mixture-of-Experts layer (GShard/Switch style).

Dispatch is sort-based (no [T, E] one-hot matmuls): token->expert assignments
are argsorted by expert id, positions within an expert computed from the
sorted order, tokens scattered into an [E, C, D] buffer, experts run as one
batched einsum (EP: expert axis sharded over 'model'), and results gathered
back with gate-weighted combine. Overflowing tokens beyond capacity C are
dropped (standard capacity-factor semantics); the router adds the usual
load-balancing auxiliary loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import _init


def moe_init(key, cfg: ModelConfig):
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff_expert, m.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": _init(ks[0], (d, E), scale=0.02),
        "w1": _init(ks[1], (E, d, f)),
        "w3": _init(ks[2], (E, d, f)),
        "w2": _init(ks[3], (E, f, d), scale=1.0 / np.sqrt(f)),
    }


def moe_capacity(cfg: ModelConfig, n_tokens: int) -> int:
    m = cfg.moe
    c = int(np.ceil(n_tokens * m.top_k * m.capacity_factor / m.num_experts))
    return max(8, ((c + 7) // 8) * 8)   # sublane-aligned


def moe_mlp(p, x, cfg: ModelConfig):
    """x: [B, S, D] -> ([B, S, D], aux_loss scalar).

    With ``dispatch_groups = G > 1`` tokens are ranked and scattered within
    G independent groups (G = data-axis size in distributed runs): the
    dispatch buffer becomes [G, E, C/G, D], shardable (data, model, ...), so
    no cross-data-shard scatter exists and GSPMD lowers dispatch to the
    intended all-to-all instead of a buffer-wide all-reduce.
    """
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K = m.num_experts, m.top_k
    G = max(1, m.dispatch_groups)
    assert T % G == 0, (T, G)
    Tl = T // G
    C = moe_capacity(cfg, Tl)

    xg = x.reshape(G, Tl, D)
    logits = (xg.astype(jnp.float32) @ p["router"]).astype(jnp.float32)  # [G,Tl,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                        # [G,Tl,K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch): E * sum_e f_e * p_e  (global)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.zeros((E,), jnp.float32).at[gate_idx.reshape(-1)].add(
        jnp.ones((T * K,), jnp.float32)) / (T * K)
    aux = m.router_aux_weight * E * jnp.sum(me * ce)

    # per-group: sort assignments by expert; rank within (group, expert)
    flat_e = gate_idx.reshape(G, Tl * K)
    flat_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Tl), K)[None], (G, Tl * K))
    flat_g = gate_vals.reshape(G, Tl * K)
    order = jnp.argsort(flat_e, axis=1)
    se = jnp.take_along_axis(flat_e, order, axis=1)
    st = jnp.take_along_axis(flat_t, order, axis=1)
    sg = jnp.take_along_axis(flat_g, order, axis=1)
    first = jax.vmap(lambda row: jnp.searchsorted(
        row, jnp.arange(E), side="left"))(se)           # [G,E]
    rank = jnp.arange(Tl * K)[None] - jnp.take_along_axis(first, se, axis=1)
    keep = rank < C
    slot = jnp.where(keep, se * C + rank, E * C)        # overflow -> dropped

    gathered = jnp.take_along_axis(xg, st[..., None], axis=1)  # [G,TlK,D]
    gathered = gathered * keep[..., None].astype(x.dtype)
    buf = jnp.zeros((G, E * C + 1, D), x.dtype)
    buf = jax.vmap(lambda b, s, v: b.at[s].add(v))(buf, slot, gathered)
    buf = buf[:, :-1].reshape(G, E, C, D)

    # expert compute (EP over 'model', groups over 'data'); swiglu
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["w1"].astype(x.dtype))) \
        * jnp.einsum("gecd,edf->gecf", buf, p["w3"].astype(x.dtype))
    y = jnp.einsum("gecf,efd->gecd", h, p["w2"].astype(x.dtype))

    # combine: gather each kept assignment's output, weight by gate
    yf = y.reshape(G, E * C, D)
    contrib = jnp.take_along_axis(
        yf, jnp.minimum(slot, E * C - 1)[..., None], axis=1)
    contrib = contrib * (sg * keep.astype(jnp.float32))[..., None].astype(x.dtype)
    out = jnp.zeros((G, Tl, D), x.dtype)
    out = jax.vmap(lambda o, s, v: o.at[s].add(v))(out, st, contrib)
    return out.reshape(B, S, D), aux
