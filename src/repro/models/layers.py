"""Transformer building blocks (pure JAX, param dicts, bf16-friendly).

Conventions:
  * params are nested dicts of jnp arrays; init fns take an rng key + config;
  * activations are [B, S, D]; attention folds heads internally;
  * every block is written to be scanned over a stacked leading layer axis;
  * sharding is applied OUTSIDE via tree-of-PartitionSpec (models/sharding.py)
    plus a few with_sharding_constraint hooks (SP at layer boundaries).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

NEG_INF = -2.3819763e38  # large negative for bf16-safe masking


def _init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ----------------------------------------------------------------- norms/rope

def rmsnorm_init(d):
    return {"w": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["w"]
    return out.astype(x.dtype)


def rope(x, pos, theta):
    """x: [B, S, H, Dh]; pos: [S] (shared) or [B, S] (per-slot decode)."""
    B, S, H, Dh = x.shape
    half = Dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    pos = jnp.asarray(pos, jnp.float32)
    if pos.ndim == 1:
        pos = pos[None, :]                                # [1, S]
    angles = pos[..., None] * freqs                       # [B', S, half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([
        x1 * cos - x2 * sin,
        x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- attention

def attention_init(key, cfg: ModelConfig, cross: bool = False):
    d, dh = cfg.d_model, cfg.head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init(ks[0], (d, H * dh)),
        "wk": _init(ks[1], (d, KV * dh)),
        "wv": _init(ks[2], (d, KV * dh)),
        "wo": _init(ks[3], (H * dh, d), scale=1.0 / np.sqrt(H * dh)),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * dh,), jnp.float32)
        p["bk"] = jnp.zeros((KV * dh,), jnp.float32)
        p["bv"] = jnp.zeros((KV * dh,), jnp.float32)
    return p


def _fold_heads(x, n, dh):
    B, S, _ = x.shape
    return x.reshape(B, S, n, dh)


def attention(p, x, cfg: ModelConfig, *, kind: str, pos_offset=0,
              cache=None, ctx=None, mask_mode="causal"):
    """Self- or cross-attention.

    kind: 'attn' (full) | 'local' (sliding window) — mask choice.
    cache: optional dict {k, v, pos} for decode; k/v are [B, KV, C, dh] with
    C = context capacity (ring buffer of size `local_window` for local
    layers). ctx: [B, T, D] cross-attention context (kind ignored, bidir).
    Returns (out [B, S, D], new_cache).
    """
    B, S, D = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    src = ctx if ctx is not None else x
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dh->bth", src, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dh->bth", src, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = _fold_heads(q, H, dh)
    k = _fold_heads(k, KV, dh)
    v = _fold_heads(v, KV, dh)

    is_cross = ctx is not None
    pos_vec = jnp.asarray(pos_offset)
    per_slot = pos_vec.ndim == 1            # [B] per-slot decode positions
    if not is_cross:
        if per_slot:
            qpos = pos_vec[:, None] + jnp.arange(S)[None, :]   # [B, S]
        else:
            qpos = pos_vec + jnp.arange(S)
        q = rope(q, qpos, cfg.rope_theta)
        k = rope(k, qpos, cfg.rope_theta)

    # Chunked/banded path (A-interval restriction; training & prefill only)
    qc = cfg.attn_q_chunk
    if (qc and cache is None and not is_cross and mask_mode == "causal"
            and S > qc and S % qc == 0):
        out = _chunked_attention(q, k, v, cfg, kind, pos_offset, qc, x.dtype)
        out = out.reshape(B, S, H * dh)
        out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))
        return out, None

    new_cache = None
    if cache is not None and not is_cross:
        # decode (S == 1): write k/v at each slot's own position
        C = cache["k"].shape[2]
        cur = jnp.broadcast_to(jnp.asarray(cache["pos"]), (B,))   # [B]
        slot = jnp.mod(cur, C) if kind == "local" else jnp.clip(cur, 0, C - 1)
        bidx = jnp.arange(B)[:, None]
        hidx = jnp.arange(KV)[None, :]
        k_new = k.transpose(0, 2, 1, 3)[:, :, 0, :].astype(cache["k"].dtype)
        v_new = v.transpose(0, 2, 1, 3)[:, :, 0, :].astype(cache["v"].dtype)
        k_c = cache["k"].at[bidx, hidx, slot[:, None]].set(k_new)
        v_c = cache["v"].at[bidx, hidx, slot[:, None]].set(v_new)
        new_cache = {"k": k_c, "v": v_c, "pos": cache["pos"] + S}
        k = k_c.transpose(0, 2, 1, 3)
        v = v_c.transpose(0, 2, 1, 3)
        Tk = C
    else:
        Tk = k.shape[1]

    # heads: group queries over kv heads (GQA); scale folded into Q (one
    # small pass instead of a full pass over the score tensor)
    group = H // KV
    q = q.reshape(B, S, KV, group, dh)
    q = q * jnp.asarray(1.0 / np.sqrt(dh), q.dtype)
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32)
    if cfg.attn_softcap is not None:
        scores = cfg.attn_softcap * jnp.tanh(scores / cfg.attn_softcap)

    if is_cross or mask_mode == "bidir":
        mask = jnp.ones((S, Tk), bool)[None]                  # [1, S, Tk]
    elif cache is not None:
        # decode: key slot t holds absolute position (ring-aware), per slot
        tpos = jnp.arange(Tk)[None, :]                        # [1, Tk]
        cur = jnp.broadcast_to(jnp.asarray(cache["pos"]), (B,))[:, None]
        if kind == "local":
            # ring buffer: slot t holds position p with p % C == t, the
            # latest such p <= cur
            delta = jnp.mod(cur - tpos, Tk)
            abs_pos = cur - delta
            mask = (abs_pos >= 0) & (abs_pos > cur - cfg.local_window)
        else:
            mask = tpos <= cur
        mask = mask[:, None, :]                               # [B, 1(S), Tk]
    else:
        qp = (pos_vec[:, None, None] + jnp.arange(S)[None, :, None]
              ) if per_slot else (pos_vec + jnp.arange(S))[None, :, None]
        kp = jnp.arange(Tk)[None, None, :]
        mask = kp <= qp
        if kind == "local":
            mask = mask & (kp > qp - cfg.local_window)        # [B', S, Tk]
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    out = out.reshape(B, S, H * dh)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(x.dtype))
    return out, new_cache


def _chunked_attention(q, k, v, cfg: ModelConfig, kind: str, pos_offset,
                       q_chunk: int, dtype):
    """Query-chunked causal/local attention with static K/V band slicing.

    This is the APRIL bridge in XLA form: per query chunk, only the KV range
    covered by the mask's A-interval is read — [0, chunk_end) for causal,
    the sliding-window band for local — so masked-out blocks cost neither
    FLOPs nor score memory (the paper's Empty cells), and the transient
    buffer shrinks from S x S to q_chunk x band.
    """
    B, S, KV, dh = k.shape[0], k.shape[1], cfg.n_kv_heads, cfg.head_dim
    H = cfg.n_heads
    group = H // KV
    q = q.reshape(B, S, KV, group, dh)
    # fold the softmax scale into Q: one pass over [B,S,H,dh] instead of a
    # full read+write over every [chunk, band] score tensor
    q = q * jnp.asarray(1.0 / np.sqrt(dh), q.dtype)
    outs = []
    for ci in range(S // q_chunk):
        lo_q = ci * q_chunk
        hi_q = lo_q + q_chunk
        if kind == "local":
            lo_k = max(0, hi_q - cfg.local_window - q_chunk + 1)
        else:
            lo_k = 0
        k_c = k[:, lo_k:hi_q]
        v_c = v[:, lo_k:hi_q]
        q_c = q[:, lo_q:hi_q]
        s = jnp.einsum("bskgh,btkh->bkgst", q_c, k_c).astype(jnp.float32)
        if cfg.attn_softcap is not None:
            s = cfg.attn_softcap * jnp.tanh(s / cfg.attn_softcap)
        qp = lo_q + jnp.arange(q_chunk)[:, None]
        kp = lo_k + jnp.arange(hi_q - lo_k)[None, :]
        mask = kp <= qp
        if kind == "local":
            mask &= kp > qp - cfg.local_window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1).astype(dtype)
        outs.append(jnp.einsum("bkgst,btkh->bskgh", pr, v_c))
    out = jnp.concatenate(outs, axis=1)
    return out.reshape(B, S, H * dh)


# ----------------------------------------------------------------- MLP / MoE

def mlp_init(key, cfg: ModelConfig, d_ff=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act in ("swiglu", "geglu"):
        return {"w1": _init(ks[0], (d, f)), "w3": _init(ks[1], (d, f)),
                "w2": _init(ks[2], (f, d), scale=1.0 / np.sqrt(f))}
    return {"w1": _init(ks[0], (d, f)),
            "w2": _init(ks[2], (f, d), scale=1.0 / np.sqrt(f))}


def mlp(p, x, cfg: ModelConfig):
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["w1"].astype(x.dtype)) * (x @ p["w3"].astype(x.dtype))
    elif cfg.act == "geglu":
        h = jax.nn.gelu(x @ p["w1"].astype(x.dtype)) * (x @ p["w3"].astype(x.dtype))
    else:  # 'gelu'
        h = jax.nn.gelu(x @ p["w1"].astype(x.dtype))
    return h @ p["w2"].astype(x.dtype)
