"""Model assembly: embedding -> pattern-cycle layer scan -> head.

Heterogeneous layer patterns (gemma2's local/global alternation,
recurrentgemma's 2x RG-LRU + local attn, llama-vision's cross-attn every 5th
layer) are handled by stacking parameters *per pattern position* and scanning
over cycles: one cycle applies `pattern_period` different sublayers, and
``lax.scan`` runs ``n_layers / period`` cycles. This keeps the HLO size
O(period) instead of O(n_layers) — crucial for multi-pod compile times —
while supporting arbitrary periodic architectures.

Modes:
  train/prefill: full-sequence forward (no cache)
  decode:        one token, stacked KV caches / recurrent states as carry
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import _init, attention, attention_init, mlp, mlp_init, \
    rmsnorm, rmsnorm_init
from .moe import moe_init, moe_mlp
from .rglru import rglru_block, rglru_init, rglru_state_init
from .ssm import ssm_block, ssm_init, ssm_state_init

P_ = None  # set lazily to avoid importing sharding at module load


def _layer_init(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 8)
    p = {"ln1": rmsnorm_init(cfg.d_model)}
    if kind in ("attn", "local", "xattn"):
        p["attn"] = attention_init(ks[0], cfg)
        if kind == "xattn":
            p["lnx"] = rmsnorm_init(cfg.d_model)
            p["xattn"] = attention_init(ks[1], cfg, cross=True)
        p["ln2"] = rmsnorm_init(cfg.d_model)
        if cfg.moe is not None:
            p["moe"] = moe_init(ks[2], cfg)
        else:
            p["mlp"] = mlp_init(ks[2], cfg)
    elif kind == "rglru":
        p["rglru"] = rglru_init(ks[0], cfg)
        p["ln2"] = rmsnorm_init(cfg.d_model)
        if cfg.moe is not None:
            p["moe"] = moe_init(ks[2], cfg)
        else:
            p["mlp"] = mlp_init(ks[2], cfg)
    elif kind == "ssm":
        p["ssm"] = ssm_init(ks[0], cfg)
    else:
        raise ValueError(kind)
    return p


def _apply_layer(p, x, cfg: ModelConfig, kind: str, *, ctx=None, cache=None,
                 pos_offset=0, mask_mode="causal"):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache
    if kind in ("attn", "local", "xattn"):
        h, nc = attention(p["attn"], rmsnorm(p["ln1"], x, cfg.norm_eps), cfg,
                          kind=("attn" if kind == "xattn" else kind),
                          pos_offset=pos_offset,
                          cache=(cache.get("kv") if cache else None),
                          mask_mode=mask_mode)
        x = x + h
        if kind == "xattn":
            hx, _ = attention(p["xattn"], rmsnorm(p["lnx"], x, cfg.norm_eps),
                              cfg, kind="attn", ctx=ctx)
            x = x + hx
        if cfg.moe is not None:
            h, aux = moe_mlp(p["moe"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
        else:
            h = mlp(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
        x = x + h
        if cache is not None:
            new_cache = dict(cache, kv=nc)
    elif kind == "rglru":
        h, ns = rglru_block(p["rglru"], rmsnorm(p["ln1"], x, cfg.norm_eps),
                            cfg, state=(cache.get("state") if cache else None))
        x = x + h
        if cfg.moe is not None:
            h, aux = moe_mlp(p["moe"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
        else:
            h = mlp(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
        x = x + h
        if cache is not None:
            new_cache = dict(cache, state=ns)
    elif kind == "ssm":
        h, ns = ssm_block(p["ssm"], rmsnorm(p["ln1"], x, cfg.norm_eps), cfg,
                          state=(cache.get("state") if cache else None))
        x = x + h
        if cache is not None:
            new_cache = dict(cache, state=ns)
    else:
        raise ValueError(kind)
    return x, new_cache, aux


def init_model(key, cfg: ModelConfig, dtype=jnp.float32):
    """Full parameter tree. Layer params stacked [n_cycles, ...] per pattern
    position ('p0', 'p1', ...). Use jax.eval_shape for abstract init."""
    keys = jax.random.split(key, 4 + cfg.n_layers)
    params = {
        "embed": _init(keys[0], (cfg.vocab, cfg.d_model), scale=0.02,
                       dtype=dtype),
        "final_norm": rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _init(keys[1], (cfg.d_model, cfg.vocab),
                                  scale=0.02, dtype=dtype)
    cyc = {}
    for pi in range(cfg.pattern_period):
        kind = cfg.block_pattern[pi]
        per_cycle = [
            _layer_init(keys[4 + c * cfg.pattern_period + pi], cfg, kind)
            for c in range(cfg.n_cycles)]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *per_cycle)
        # weights (stacked ndim >= 3) go to the compute dtype; norms/biases
        # and other small 1D vectors stay f32
        cyc[f"p{pi}"] = jax.tree.map(
            lambda a: a.astype(dtype) if a.ndim >= 3 else a, stacked)
    params["cycle"] = cyc
    if cfg.tail_kinds:
        tail_keys = jax.random.split(keys[3], len(cfg.tail_kinds))
        params["tail"] = {
            f"t{i}": jax.tree.map(
                lambda a: a.astype(dtype) if a.ndim >= 2 else a,
                _layer_init(tail_keys[i], cfg, kind))
            for i, kind in enumerate(cfg.tail_kinds)}
    if cfg.encoder is not None:
        enc_keys = jax.random.split(keys[2], cfg.encoder.n_layers)
        enc_layers = [_layer_init(k, cfg, "attn") for k in enc_keys]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *enc_layers)
        params["encoder"] = {
            "layers": jax.tree.map(
                lambda a: a.astype(dtype) if a.ndim >= 3 else a, stacked),
            "final_norm": rmsnorm_init(cfg.d_model),
        }
    return params


def _sinusoid(S, D):
    pos = np.arange(S)[:, None]
    dim = np.arange(0, D, 2)[None, :] / D
    ang = pos / (10000 ** dim)
    out = np.zeros((S, D), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)


def run_encoder(params, frames, cfg: ModelConfig, remat_policy=None,
                unroll=False):
    """Whisper-style encoder over precomputed frame embeddings [B, T, D]."""
    x = frames + _sinusoid(frames.shape[1], cfg.d_model).astype(frames.dtype)

    def enc_layer(x, p):
        x, _, _ = _apply_layer(p, x, cfg, "attn", mask_mode="bidir")
        return x, None

    body = enc_layer
    if remat_policy is not None:
        body = jax.checkpoint(enc_layer, policy=remat_policy)
    if unroll:
        n = jax.tree.leaves(params["encoder"]["layers"])[0].shape[0]
        for c in range(n):
            x, _ = body(x, jax.tree.map(lambda a: a[c],
                                        params["encoder"]["layers"]))
    else:
        x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
    return rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def _one_layer_cache(cfg: ModelConfig, kind: str, batch: int, ctx_len: int,
                     dtype):
    KV, dh = cfg.n_kv_heads, cfg.head_dim
    if kind in ("attn", "xattn"):
        return {"kv": {
            "k": jnp.zeros((batch, KV, ctx_len, dh), dtype),
            "v": jnp.zeros((batch, KV, ctx_len, dh), dtype),
            "pos": jnp.zeros((batch,), jnp.int32)}}
    if kind == "local":
        w = min(ctx_len, cfg.local_window)
        return {"kv": {
            "k": jnp.zeros((batch, KV, w, dh), dtype),
            "v": jnp.zeros((batch, KV, w, dh), dtype),
            "pos": jnp.zeros((batch,), jnp.int32)}}
    if kind == "rglru":
        return {"state": rglru_state_init(cfg, batch, dtype)}
    if kind == "ssm":
        return {"state": ssm_state_init(cfg, batch, dtype)}
    raise ValueError(kind)


def build_caches(cfg: ModelConfig, batch: int, ctx_len: int, dtype=jnp.bfloat16):
    """Decode caches: {'cycle': stacked per pattern position, 'tail': ...}."""
    n = cfg.n_cycles
    cycle = {}
    for pi, kind in enumerate(cfg.block_pattern):
        one = _one_layer_cache(cfg, kind, batch, ctx_len, dtype)
        cycle[f"p{pi}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape).astype(a.dtype), one)
    tail = {f"t{i}": _one_layer_cache(cfg, kind, batch, ctx_len, dtype)
            for i, kind in enumerate(cfg.tail_kinds)}
    return {"cycle": cycle, "tail": tail}


def set_cache_pos(caches, pos):
    """Mark all kv caches as holding ``pos`` tokens (decode position)."""
    def setp(tree):
        if isinstance(tree, dict) and "pos" in tree:
            new = jnp.broadcast_to(jnp.asarray(pos, jnp.int32),
                                   tree["pos"].shape)
            return dict(tree, pos=new)
        if isinstance(tree, dict):
            return {k: setp(v) for k, v in tree.items()}
        return tree
    return setp(caches)


def forward_logits(params, tokens, cfg: ModelConfig, *, ctx=None,
                   caches=None, pos_offset=0, remat_policy=None,
                   activation_hook=None, unroll=False):
    """tokens: [B, S] int32 -> logits [B, S, V] (f32).

    caches: stacked decode caches (S must be 1). ctx: cross-attn context
    (VLM patches / whisper encoder output). activation_hook(x, where) lets
    the sharding layer constrain layer-boundary activations (SP).
    ``unroll=True`` replaces the cycle scan with a Python loop — used by the
    dry-run's FLOP-probe lowers (XLA cost analysis counts while-loop bodies
    once, so scanned cells are corrected via unrolled 1/2-cycle probes).
    """
    hook = activation_hook or (lambda x, where: x)
    emb = params["embed"]
    x = emb[tokens] * jnp.asarray(np.sqrt(cfg.d_model), emb.dtype)
    x = hook(x, "embed")

    def cycle_fn(carry, xs):
        x = carry
        cyc_params, cyc_caches = xs
        new_caches = {} if cyc_caches is not None else None
        aux_total = jnp.zeros((), jnp.float32)
        for pi, kind in enumerate(cfg.block_pattern):
            cache = cyc_caches[f"p{pi}"] if cyc_caches is not None else None
            x, nc, aux = _apply_layer(
                cyc_params[f"p{pi}"], x, cfg, kind, ctx=ctx, cache=cache,
                pos_offset=pos_offset)
            aux_total = aux_total + aux
            if new_caches is not None:
                new_caches[f"p{pi}"] = nc
            x = hook(x, "layer")
        return x, (new_caches, aux_total)

    body = cycle_fn
    if remat_policy is not None:
        body = jax.checkpoint(cycle_fn, policy=remat_policy)
    cycle_caches = caches.get("cycle") if caches is not None else None
    if unroll:
        nc_acc, aux_acc = [], []
        for c in range(cfg.n_cycles):
            cyc_p = jax.tree.map(lambda a: a[c], params["cycle"])
            cyc_c = (jax.tree.map(lambda a: a[c], cycle_caches)
                     if cycle_caches is not None else None)
            x, (nc, aux) = body(x, (cyc_p, cyc_c))
            nc_acc.append(nc)
            aux_acc.append(aux)
        new_cycle = (jax.tree.map(lambda *xs: jnp.stack(xs), *nc_acc)
                     if cycle_caches is not None else None)
        auxs = jnp.stack(aux_acc)
    else:
        x, (new_cycle, auxs) = jax.lax.scan(
            body, x, (params["cycle"], cycle_caches))

    # unscanned tail layers (n_layers % pattern_period remainder)
    new_tail = {} if caches is not None else None
    aux_tail = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(cfg.tail_kinds):
        tc = caches["tail"][f"t{i}"] if caches is not None else None
        x, nc, aux = _apply_layer(params["tail"][f"t{i}"], x, cfg, kind,
                                  ctx=ctx, cache=tc, pos_offset=pos_offset)
        aux_tail = aux_tail + aux
        if new_tail is not None:
            new_tail[f"t{i}"] = nc
        x = hook(x, "layer")

    new_caches = (None if caches is None
                  else {"cycle": new_cycle, "tail": new_tail})
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    x = hook(x, "final")

    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))
    logits = logits.astype(jnp.float32)
    if cfg.logit_softcap is not None:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    logits = hook(logits, "logits")
    aux = jnp.sum(auxs) + aux_tail
    return logits, new_caches, aux
