"""Assigned-architecture substrate: pure-JAX transformer/SSM/MoE stack.

Param trees are plain nested dicts of jnp arrays; layers are stacked along a
leading axis per pattern position and executed with lax.scan (small HLO,
fast multi-pod compiles). See models/model.py for the assembly.
"""
from .config import ModelConfig, MoEConfig, SSMConfig, EncoderConfig  # noqa: F401
from .model import init_model, forward_logits  # noqa: F401
