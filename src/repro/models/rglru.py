"""RG-LRU recurrent block (Griffin / recurrentgemma-2b).

Block = gated dual branch: GeLU(gate) ⊙ (conv1d -> RG-LRU), projected back.
RG-LRU: r_t = σ(W_r x), i_t = σ(W_i x), a_t = a^{c·r_t} with a = σ(Λ),
h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t). Diagonal recurrence →
associative scan for training, O(1) carry for decode (hence long_500k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import _init
from .ssm import _causal_conv

C_COEF = 8.0


def rglru_init(key, cfg: ModelConfig):
    d = cfg.d_model
    dr = cfg.d_model           # recurrent width = d_model
    ks = jax.random.split(key, 6)
    return {
        "in_x": _init(ks[0], (d, dr)),
        "in_g": _init(ks[1], (d, dr)),
        "conv_w": _init(ks[2], (4, dr), scale=0.2),
        "conv_b": jnp.zeros((dr,), jnp.float32),
        "w_r": _init(ks[3], (dr, dr)),
        "w_i": _init(ks[4], (dr, dr)),
        "lam": jnp.full((dr,), 2.0, jnp.float32),   # σ(2)^8 ≈ .35 decay
        "out": _init(ks[5], (dr, d)),
    }


def rglru_block(p, x, cfg: ModelConfig, state=None):
    """x: [B, S, D]; state: None or {h: [B,DR] f32, conv: [B,3,DR]}."""
    B, S, D = x.shape
    g = jax.nn.gelu(x @ p["in_g"].astype(x.dtype))
    xr = x @ p["in_x"].astype(x.dtype)
    conv_state = state["conv"] if state is not None else None
    xr, new_conv = _causal_conv(xr, p["conv_w"], p["conv_b"], conv_state)

    r = jax.nn.sigmoid((xr @ p["w_r"].astype(x.dtype)).astype(jnp.float32))
    i = jax.nn.sigmoid((xr @ p["w_i"].astype(x.dtype)).astype(jnp.float32))
    log_a = -C_COEF * jax.nn.softplus(p["lam"]) * r       # log a_t  [B,S,DR]
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i \
        * xr.astype(jnp.float32)

    if state is None:
        def combine(c1, c2):
            a1, u1 = c1
            a2, u2 = c2
            return a1 * a2, u1 * a2 + u2
        _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
        new_h = None
    else:
        h = a[:, 0] * state["h"] + gated[:, 0]
        new_h = h
        h = h[:, None, :]
    y = (h.astype(x.dtype) * g) @ p["out"].astype(x.dtype)
    new_state = None if state is None else {"h": new_h, "conv": new_conv}
    return y, new_state


def rglru_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    dr = cfg.d_model
    return {"h": jnp.zeros((batch, dr), jnp.float32),
            "conv": jnp.zeros((batch, 3, dr), dtype)}
