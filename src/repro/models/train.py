"""Training step: loss, grads, AdamW — assembled for pjit.

Loss is next-token cross-entropy computed against vocab-sharded logits (the
log-sum-exp reduction crosses the 'model' axis; GSPMD inserts the
all-reduce). MoE architectures add the router load-balancing aux loss.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..optim.adamw import adamw_update
from .config import ModelConfig
from .model import forward_logits, run_encoder


def loss_fn(params, batch, cfg: ModelConfig, *, remat_policy=None,
            activation_hook=None, unroll=False):
    tokens = batch["tokens"]
    labels = batch["labels"]
    ctx = None
    if cfg.encoder is not None:
        ctx = run_encoder(params, batch["frames"], cfg,
                          remat_policy=remat_policy, unroll=unroll)
    elif cfg.n_patch_tokens:
        ctx = batch["patches"]
    logits, _, aux = forward_logits(
        params, tokens, cfg, ctx=ctx, remat_policy=remat_policy,
        activation_hook=activation_hook, unroll=unroll)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    xent = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return xent + aux, {"xent": xent, "aux": aux}


def make_train_step(cfg: ModelConfig, *, lr=3e-4, remat_policy="dots",
                    activation_hook=None, unroll=False, grad_shardings=None,
                    microbatch: int | None = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    grad_shardings: optional NamedSharding tree (the ZeRO-1 opt-state specs).
    Constraining gradients to the optimizer-shard layout turns the DP
    gradient all-reduce into reduce-scatter + per-shard update + param
    all-gather — the ZeRO-1 communication pattern (§Perf iteration).

    microbatch: gradient accumulation over N batch splits — divides the
    activation footprint ~N x with no extra collectives (grads accumulate
    locally before the one DP reduction).
    """
    policy = {
        None: None,
        "none": None,
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        "nothing": jax.checkpoint_policies.nothing_saveable,
        "everything": jax.checkpoint_policies.everything_saveable,
    }[remat_policy]

    grad_fn = jax.value_and_grad(
        functools.partial(loss_fn, cfg=cfg, remat_policy=policy,
                          activation_hook=activation_hook, unroll=unroll),
        has_aux=True)

    def train_step(params, opt_state, batch):
        n_mb = microbatch or 1
        if n_mb > 1:
            loss = jnp.zeros((), jnp.float32)
            metrics = None
            grads = None
            for i in range(n_mb):
                mb = jax.tree.map(lambda a: a[i::n_mb], batch)
                (l, m), g = grad_fn(params, mb)
                loss = loss + l
                metrics = m if metrics is None else \
                    jax.tree.map(jnp.add, metrics, m)
                grads = g if grads is None else jax.tree.map(jnp.add, grads, g)
            inv = 1.0 / n_mb
            loss = loss * inv
            metrics = jax.tree.map(lambda a: a * inv, metrics)
            grads = jax.tree.map(lambda a: (a * inv).astype(a.dtype), grads)
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        if grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        new_params, new_opt = adamw_update(params, grads, opt_state, lr=lr)
        metrics = dict(metrics, loss=loss,
                       grad_norm=_global_norm(grads))
        return new_params, new_opt, metrics

    return train_step


def _global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))
