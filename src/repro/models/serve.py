"""Serving steps: prefill (full forward) and single-token decode with
stacked KV caches / recurrent states."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .model import build_caches, forward_logits, run_encoder, set_cache_pos


def make_prefill_step(cfg: ModelConfig, activation_hook=None, unroll=False):
    def prefill_step(params, batch):
        ctx = None
        if cfg.encoder is not None:
            ctx = run_encoder(params, batch["frames"], cfg, unroll=unroll)
        elif cfg.n_patch_tokens:
            ctx = batch["patches"]
        logits, _, _ = forward_logits(params, batch["tokens"], cfg, ctx=ctx,
                                      activation_hook=activation_hook,
                                      unroll=unroll)
        return logits[:, -1, :]
    return prefill_step


def make_decode_step(cfg: ModelConfig, activation_hook=None, unroll=False):
    """decode_step(params, caches, batch) -> (logits [B, V], new_caches).

    batch: {'tokens': [B, 1], 'pos': scalar int32 (current KV length),
    optional 'frames'/'patches' ctx}.
    """
    def decode_step(params, caches, batch):
        ctx = None
        if cfg.encoder is not None:
            ctx = run_encoder(params, batch["frames"], cfg, unroll=unroll)
        elif cfg.n_patch_tokens:
            ctx = batch["patches"]
        caches = set_cache_pos(caches, batch["pos"])
        logits, new_caches, _ = forward_logits(
            params, batch["tokens"], cfg, ctx=ctx, caches=caches,
            pos_offset=batch["pos"], activation_hook=activation_hook,
            unroll=unroll)
        return logits[:, 0, :], new_caches
    return decode_step


def greedy_generate(params, cfg: ModelConfig, prompt, steps: int,
                    ctx_capacity: int | None = None, batch_extra=None):
    """Host-loop greedy decoding (examples/tests): prefill via repeated
    decode for simplicity."""
    B, S0 = prompt.shape
    cap = ctx_capacity or (S0 + steps)
    caches = build_caches(cfg, B, cap, dtype=jnp.float32)
    decode = jax.jit(make_decode_step(cfg))
    toks = prompt
    out = []
    for t in range(S0 + steps - 1):
        batch = {"tokens": toks[:, t: t + 1],
                 "pos": jnp.asarray(t, jnp.int32)}
        if batch_extra:
            batch.update(batch_extra)
        logits, caches = decode(params, caches, batch)
        nxt = jnp.argmax(logits, axis=-1)[:, None]
        if t >= S0 - 1:
            out.append(nxt)
            toks = jnp.concatenate([toks, nxt], axis=1)
    return jnp.concatenate(out, axis=1) if out else jnp.zeros((B, 0), jnp.int32)
