"""Mamba-1 selective SSM block (falcon-mamba-7b architecture).

Recurrence h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t ; y_t = C_t h_t + D x.
Training uses an associative scan over the diagonal state (chunked by the
caller's remat policy; the d_inner axis is TP-sharded so the materialized
[B, S, DI_shard, N] scan operands stay within HBM). Decode keeps (conv
window, state) as explicit carry — O(1) per token, the reason this arch runs
``long_500k``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import _init


def ssm_init(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    dt_rank = max(1, int(np.ceil(d / 16)))
    ks = jax.random.split(key, 6)
    A = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32)[None, :],
                 (di, 1))
    return {
        "in_proj": _init(ks[0], (d, 2 * di)),
        "conv_w": _init(ks[1], (s.d_conv, di), scale=0.2),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": _init(ks[2], (di, dt_rank + 2 * s.d_state)),
        "dt_proj": _init(ks[3], (dt_rank, di), scale=0.1),
        "dt_bias": jnp.full((di,), -4.0, jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _init(ks[4], (di, d)),
    }


def _causal_conv(x, w, b, state=None):
    """x: [B, S, DI]; w: [K, DI] depthwise causal conv.
    state: [B, K-1, DI] previous inputs for decode. Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i: i + x.shape[1], :] * w[i].astype(x.dtype)
            for i in range(K))
    y = y + b.astype(x.dtype)
    new_state = xp[:, -(K - 1):, :] if K > 1 else None
    return y, new_state


def ssm_block(p, x, cfg: ModelConfig, state=None):
    """x: [B, S, D]. state: None (train) or dict {h: [B,DI,N], conv: [B,K-1,DI]}.
    Returns (y [B,S,D], new_state)."""
    s = cfg.ssm
    B, S, D = x.shape
    di = s.expand * D
    N = s.d_state
    dt_rank = p["dt_proj"].shape[0]

    xz = x @ p["in_proj"].astype(x.dtype)               # [B,S,2DI]
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_state = state["conv"] if state is not None else None
    xi, new_conv = _causal_conv(xi, p["conv_w"], p["conv_b"], conv_state)
    xi = jax.nn.silu(xi)

    proj = xi @ p["x_proj"].astype(x.dtype)             # [B,S,dt_rank+2N]
    dt = proj[..., :dt_rank] @ p["dt_proj"].astype(x.dtype) \
        + p["dt_bias"].astype(x.dtype)
    dt = jax.nn.softplus(dt.astype(jnp.float32))        # [B,S,DI]
    Bm = proj[..., dt_rank: dt_rank + N].astype(jnp.float32)   # [B,S,N]
    Cm = proj[..., dt_rank + N:].astype(jnp.float32)           # [B,S,N]

    A = -jnp.exp(p["A_log"])                            # [DI,N]
    decay = jnp.exp(dt[..., None] * A[None, None])      # [B,S,DI,N]
    drive = (dt * xi.astype(jnp.float32))[..., None] * Bm[:, :, None, :]

    if state is None:
        def combine(a, b):
            d1, u1 = a
            d2, u2 = b
            return d1 * d2, u1 * d2 + u2
        _, hs = jax.lax.associative_scan(combine, (decay, drive), axis=1)
        h = hs                                           # [B,S,DI,N]
        y = jnp.einsum("bsdn,bsn->bsd", h, Cm)
        new_h = None
    else:
        h0 = state["h"]                                  # [B,DI,N] f32
        h = decay[:, 0] * h0 + drive[:, 0]
        y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0])[:, None, :]
        new_h = h
    y = y + xi.astype(jnp.float32) * p["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ p["out_proj"].astype(x.dtype)
    new_state = None if state is None else {"h": new_h, "conv": new_conv}
    return out, new_state


def ssm_state_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    return {"h": jnp.zeros((batch, di, s.d_state), jnp.float32),
            "conv": jnp.zeros((batch, s.d_conv - 1, di), dtype)}
