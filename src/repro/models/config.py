"""Model configuration covering all 10 assigned architecture families."""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    # >1: grouped 2D dispatch — tokens are ranked/scattered within
    # dispatch_groups groups (set = data-axis size) so the [G, E, C, D]
    # buffer shards (data, model) and the global-scatter all-reduce
    # pathology disappears (§Perf bonus iteration)
    dispatch_groups: int = 1


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (whisper). The audio conv frontend is
    a STUB per the assignment: input_specs() feeds precomputed frame
    embeddings of shape [B, n_frames, d_model]."""
    n_layers: int
    n_frames: int = 1500


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                      # 0 => d_model // n_heads
    # Per-layer block pattern, cycled over n_layers. Kinds:
    #   'attn'  full self-attention      'local' sliding-window attention
    #   'rglru' RG-LRU recurrent block   'ssm'   mamba1 block
    #   'xattn' self-attn + cross-attn (VLM/enc-dec decoder layers)
    block_pattern: tuple = ("attn",)
    local_window: int = 4096
    qkv_bias: bool = False
    attn_softcap: float | None = None
    logit_softcap: float | None = None
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    act: str = "swiglu"                  # 'swiglu' | 'gelu'
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    encoder: EncoderConfig | None = None
    n_patch_tokens: int = 0              # VLM stub frontend token count
    tie_embeddings: bool = False
    # families: 'dense' | 'moe' | 'ssm' | 'hybrid' | 'vlm' | 'audio'
    family: str = "dense"
    # shapes eligible for long_500k (sub-quadratic archs only)
    supports_long_context: bool = False
    # perf knobs (hillclimb; see EXPERIMENTS.md §Perf):
    #   attn_q_chunk: query-chunked attention — causal chunks slice K/V to
    #   [0, chunk_end) and local chunks to the window band, i.e. the APRIL
    #   A-interval restriction of the mask expressed in XLA. Cuts the S x S
    #   score buffer to chunk x band and drops masked-out FLOPs.
    attn_q_chunk: int | None = None

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def pattern_period(self) -> int:
        return len(self.block_pattern)

    @property
    def n_cycles(self) -> int:
        """Scanned cycles; remainder layers become the unscanned tail."""
        return self.n_layers // self.pattern_period

    @property
    def tail_kinds(self) -> tuple:
        """Layers beyond the last full cycle (e.g. Griffin's trailing R, R
        after eight (R, R, A) triples), applied after the scan."""
        return tuple(self.block_pattern[: self.n_layers % self.pattern_period])

    def layer_kinds(self) -> list[str]:
        return [self.block_pattern[i % self.pattern_period]
                for i in range(self.n_layers)]

    def param_count(self) -> int:
        """Approximate parameter count (embeddings included)."""
        d, dh = self.d_model, self.head_dim
        total = self.vocab * d * (1 if self.tie_embeddings else 2)
        for kind in self.layer_kinds():
            if kind in ("attn", "local", "xattn"):
                attn = d * dh * (self.n_heads + 2 * self.n_kv_heads) \
                    + self.n_heads * dh * d
                if kind == "xattn":
                    attn *= 2
                total += attn
            elif kind == "rglru":
                dr = self.d_ff  # recurrent width ~ d_ff? use d_model
                total += 2 * d * d + 2 * d
            elif kind == "ssm":
                di = self.ssm.expand * d
                total += d * di * 2 + di * (self.ssm.d_state * 2 + 1) + di * d
            if self.moe is not None:
                total += self.moe.num_experts * 3 * d * self.moe.d_ff_expert \
                    + d * self.moe.num_experts
            elif kind != "ssm":
                mults = 3 if self.act == "swiglu" else 2
                total += mults * d * self.d_ff
        if self.encoder is not None:
            enc_layer = 4 * d * dh * self.n_heads + 2 * 2 * d * self.d_ff
            total += self.encoder.n_layers * enc_layer
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.n_layers * (
            self.moe.num_experts * 3 * d * self.moe.d_ff_expert)
        return int(dense + self.n_layers * self.moe.top_k * 3 * d
                   * self.moe.d_ff_expert)
