"""Sharding rules: param/optimizer/cache PartitionSpec trees + activation
hooks (DP over 'data' (+'pod'), TP/EP over 'model', SP at layer boundaries,
ZeRO-1 optimizer-state sharding over 'data').

Rules are name-based over the param tree paths — one table covers every
architecture family. Head counts that don't divide the model axis rely on
GSPMD's padded uneven sharding (documented in DESIGN.md).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["param_specs", "opt_state_specs", "cache_specs",
           "make_activation_hook", "data_axes", "named_sharding_tree"]


def data_axes(mesh: Mesh):
    """The data-parallel axes: ('pod', 'data') on multi-pod meshes."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# (path-suffix match, spec builder) — first match wins. Specs are for the
# UNSTACKED layer params; a leading None is prepended for stacked trees.
def _rules():
    M = "model"
    return [
        (("embed",), P(M, None)),
        (("lm_head",), P(None, M)),
        (("attn", "wq"), P(None, M)), (("attn", "wk"), P(None, M)),
        (("attn", "wv"), P(None, M)), (("attn", "wo"), P(M, None)),
        (("attn", "bq"), P(M)), (("attn", "bk"), P(M)), (("attn", "bv"), P(M)),
        (("xattn", "wq"), P(None, M)), (("xattn", "wk"), P(None, M)),
        (("xattn", "wv"), P(None, M)), (("xattn", "wo"), P(M, None)),
        (("mlp", "w1"), P(None, M)), (("mlp", "w3"), P(None, M)),
        (("mlp", "w2"), P(M, None)),
        (("moe", "router"), P(None, None)),
        (("moe", "w1"), P(M, None, None)), (("moe", "w3"), P(M, None, None)),
        (("moe", "w2"), P(M, None, None)),
        (("ssm", "in_proj"), P(None, M)), (("ssm", "conv_w"), P(None, M)),
        (("ssm", "conv_b"), P(M)), (("ssm", "x_proj"), P(M, None)),
        (("ssm", "dt_proj"), P(None, M)), (("ssm", "dt_bias"), P(M)),
        (("ssm", "A_log"), P(M, None)), (("ssm", "D"), P(M)),
        (("ssm", "out_proj"), P(M, None)),
        (("rglru", "in_x"), P(None, M)), (("rglru", "in_g"), P(None, M)),
        (("rglru", "conv_w"), P(None, M)), (("rglru", "conv_b"), P(M)),
        (("rglru", "w_r"), P(None, M)), (("rglru", "w_i"), P(None, M)),
        (("rglru", "lam"), P(M)), (("rglru", "out"), P(M, None)),
    ]


def _axis_size(mesh: Mesh | None, axis) -> int:
    if mesh is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return int(mesh.shape[axis])


def _sanitize(spec: P, shape, mesh: Mesh | None) -> P:
    """Drop sharded axes whose dimension is not divisible by the mesh axis —
    jit in_shardings require exact divisibility (unlike GSPMD-internal
    constraints, which pad)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, ax in zip(shape, parts):
        if ax is None or dim % _axis_size(mesh, ax) == 0:
            out.append(ax)
        else:
            out.append(None)
    return P(*out)


def _spec_for_path(path, leaf, stacked: bool, mesh: Mesh | None = None):
    names = tuple(getattr(k, "key", getattr(k, "name", str(k))) for k in path)
    for suffix, spec in _rules():
        if names[-len(suffix):] == suffix:
            if stacked and ("cycle" in names or "layers" in names):
                spec = P(*((None,) + tuple(spec)))
            return _sanitize(spec, leaf.shape, mesh)
    # norms, scalars: replicated
    return P(*([None] * leaf.ndim))


def param_specs(params_shape, mesh: Mesh | None = None) -> dict:
    """PartitionSpec tree matching a param (shape) tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for_path(path, leaf, stacked=True, mesh=mesh),
        params_shape)


def opt_state_specs(params_shape, mesh: Mesh) -> dict:
    """ZeRO-1 specs for {'m': params, 'v': params, 'step': scalar}: moment
    tensors additionally sharded over the data axes on the first dimension
    that is divisible and not already sharded."""
    daxes = data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1

    def zero1(path, leaf):
        spec = _spec_for_path(path, leaf, stacked=True, mesh=mesh)
        parts = list(spec) + [None] * (leaf.ndim - len(spec))
        for i, (dim, cur) in enumerate(zip(leaf.shape, parts)):
            if cur is None and dim % dsize == 0 and dim >= dsize > 1:
                parts[i] = daxes if len(daxes) > 1 else daxes[0]
                break
        return P(*parts)

    moments = jax.tree_util.tree_map_with_path(zero1, params_shape)
    return {"m": moments, "v": moments, "step": P()}


def cache_specs(caches_shape, mesh: Mesh) -> dict:
    """KV caches: [n_cycles, B, KV, C, dh] -> batch over data, heads over
    model; recurrent states [n, B, W...] -> batch over data, width over model."""
    daxes = data_axes(mesh)
    d = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)

    def spec(path, leaf):
        names = tuple(getattr(k, "key", getattr(k, "name", str(k))) for k in path)
        if names[-1] == "pos":
            return P(*([None] * leaf.ndim))
        stacked = "cycle" in names          # leading n_cycles axis
        lead = (None,) if stacked else ()
        if names[-1] in ("k", "v"):         # [.., B, KV, C, dh]
            kv_dim = leaf.shape[1 + int(stacked)]
            if kv_dim % _axis_size(mesh, "model") == 0:
                s = P(*lead, d, "model", None, None)
            else:
                # kv heads don't divide the model axis: shard head_dim
                # (always 128-multiple) so giant decode caches still split
                s = P(*lead, d, None, None, "model")
        elif names[-1] == "h":              # [.., B, DI, N] or [.., B, DR]
            if leaf.ndim == 3 + int(stacked):
                s = P(*lead, d, "model", None)
            else:
                s = P(*lead, d, "model")
        elif names[-1] == "conv":           # [.., B, K-1, DI]
            s = P(*lead, d, None, "model")
        else:
            return P(*([None] * leaf.ndim))
        return _sanitize(s, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec, caches_shape)


def make_activation_hook(mesh: Mesh, *, sequence_parallel: bool = True,
                         decode: bool = False):
    """Layer-boundary sharding constraints: batch over data axes; sequence
    over 'model' at cycle boundaries (SP) to cut saved-activation memory."""
    daxes = data_axes(mesh)
    d = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)

    def hook(x, where):
        if x.ndim != 3:
            return x
        if where in ("embed", "layer", "final"):
            if sequence_parallel and not decode:
                spec = P(d, "model", None)
            else:
                spec = P(d, None, None)
        elif where == "logits":
            spec = P(d, None, "model")
        else:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    return hook


def named_sharding_tree(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))
